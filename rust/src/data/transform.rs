//! Composable input transforms (§6.1): the [`Transform`] trait and the
//! per-lane [`TransformChain`] replacing the old fixed `Augment` struct.
//!
//! A chain owns **one** RNG and threads it through every transform in
//! order, so the standard chain built from [`AugmentCfg`] (erase → running
//! mixup) consumes the exact RNG stream the pre-refactor `Augment` did —
//! `tests/data_pipeline.rs` pins that bit-parity. Transforms keep their
//! non-RNG state (e.g. the running-mixup virtual batch) in `&mut self`,
//! which is per-lane state: the loader builds one chain per global lane,
//! keyed by the lane index, so the augment stream is invariant to the
//! worker count.

use crate::ckpt::{ByteReader, ByteWriter, CkptError};
use crate::data::source::Batch;
use crate::util::rng::Rng;

/// Configuration of the standard augmentation chain: running mixup
/// (Eqs. 18-19) and zero-valued random erasing, as the paper's DALI
/// pipeline applied them.
#[derive(Clone, Debug)]
pub struct AugmentCfg {
    /// Beta(α, α) parameter for mixup; 0 disables mixup.
    pub alpha_mixup: f64,
    /// random-erasing probability (paper: 0.5); 0 disables erasing.
    pub erase_p: f64,
    /// erasing area ratio range (paper: [0.02, 0.25])
    pub erase_area: (f64, f64),
    /// erasing aspect ratio range (paper: [0.3, 1.0])
    pub erase_aspect: (f64, f64),
}

impl Default for AugmentCfg {
    fn default() -> Self {
        AugmentCfg {
            alpha_mixup: 0.4,
            erase_p: 0.5,
            erase_area: (0.02, 0.25),
            erase_aspect: (0.3, 1.0),
        }
    }
}

impl AugmentCfg {
    pub fn disabled() -> Self {
        AugmentCfg { alpha_mixup: 0.0, erase_p: 0.0, ..Default::default() }
    }
}

/// One composable batch transform. `apply` receives the chain's RNG; a
/// transform must consume it deterministically (same draws for the same
/// input shape) or not at all — that is what keeps the pipeline bitwise
/// reproducible and prefetch-schedule-independent.
pub trait Transform: Send {
    fn name(&self) -> &'static str;

    /// Output (C, H, W) for a given input geometry (identity by default;
    /// geometry-changing transforms like [`Downsample`] override).
    fn out_shape(&self, shape: (usize, usize, usize)) -> (usize, usize, usize) {
        shape
    }

    fn apply(&mut self, batch: Batch, rng: &mut Rng) -> Batch;

    /// Serialize the transform's mutable (non-RNG) state for a
    /// checkpoint. Empty — the default — is correct for stateless
    /// transforms; [`RunningMixup`] persists its virtual batch.
    fn state_save(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state written by [`Transform::state_save`]. The default
    /// accepts only the empty payload it saves.
    fn state_load(&mut self, bytes: &[u8]) -> Result<(), CkptError> {
        if bytes.is_empty() {
            Ok(())
        } else {
            Err(CkptError::BadPayload("unexpected state for a stateless transform"))
        }
    }
}

/// An ordered chain of transforms sharing one RNG stream. Built per lane
/// (see [`lane_chain_seed`]).
pub struct TransformChain {
    rng: Rng,
    items: Vec<Box<dyn Transform>>,
}

/// The per-lane chain seed derivation — identical to the pre-refactor
/// per-lane `Augment` seeding (`(trainer_seed ^ lane<<8) ^ 0xA06_3E27`),
/// so `synth` training streams are unchanged by the redesign.
pub fn lane_chain_seed(trainer_seed: u64, lane: usize) -> u64 {
    (trainer_seed ^ ((lane as u64) << 8)) ^ 0xA06_3E27
}

impl TransformChain {
    /// An empty (identity) chain with its RNG seeded directly.
    pub fn new(seed: u64) -> Self {
        TransformChain { rng: Rng::new(seed), items: Vec::new() }
    }

    /// The standard augmentation chain for `cfg`: random erasing then
    /// running mixup, each included only when enabled (a disabled stage
    /// consumes no RNG draws — matching the old `Augment` exactly).
    /// `seed` is the lane seed *before* the legacy `^ 0xA06_3E27` mix,
    /// i.e. pass `trainer_seed ^ (lane << 8)` or use [`lane_chain_seed`]
    /// via [`TransformChain::standard_for_lane`].
    pub fn standard(cfg: &AugmentCfg, seed: u64) -> Self {
        let mut chain = TransformChain::new(seed ^ 0xA06_3E27);
        chain.extend_standard(cfg);
        chain
    }

    /// The standard chain for global lane `lane` of a trainer seeded with
    /// `trainer_seed`.
    pub fn standard_for_lane(cfg: &AugmentCfg, trainer_seed: u64, lane: usize) -> Self {
        let rng = Rng::new(lane_chain_seed(trainer_seed, lane));
        let mut chain = TransformChain { rng, items: Vec::new() };
        chain.extend_standard(cfg);
        chain
    }

    /// Append the standard augmentation stages enabled in `cfg`.
    pub fn extend_standard(&mut self, cfg: &AugmentCfg) {
        if cfg.erase_p > 0.0 {
            self.push(Box::new(RandomErase {
                p: cfg.erase_p,
                area: cfg.erase_area,
                aspect: cfg.erase_aspect,
            }));
        }
        if cfg.alpha_mixup > 0.0 {
            self.push(Box::new(RunningMixup { alpha: cfg.alpha_mixup, prev: None }));
        }
    }

    /// Append a transform to the end of the chain.
    pub fn push(&mut self, t: Box<dyn Transform>) {
        self.items.push(t);
    }

    /// Insert a transform at the front (runs before everything else —
    /// used for geometry adapters like [`Downsample`]).
    pub fn push_front(&mut self, t: Box<dyn Transform>) {
        self.items.insert(0, t);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The chain's output geometry for a given source geometry.
    pub fn out_shape(&self, mut shape: (usize, usize, usize)) -> (usize, usize, usize) {
        for t in &self.items {
            shape = t.out_shape(shape);
        }
        shape
    }

    /// Run the batch through every transform in order, sharing the
    /// chain's RNG stream.
    pub fn apply(&mut self, mut batch: Batch) -> Batch {
        for t in self.items.iter_mut() {
            batch = t.apply(batch, &mut self.rng);
        }
        batch
    }

    /// Checkpoint the chain: the shared RNG stream plus every
    /// transform's state blob, in order.
    pub fn state_save(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.rng_state(self.rng.state());
        w.u16(self.items.len() as u16);
        for t in &self.items {
            w.blob(&t.state_save());
        }
        w.into_inner()
    }

    /// Restore a [`TransformChain::state_save`] snapshot into a chain of
    /// the same construction (the structure comes from config; only the
    /// mutable state comes from the checkpoint).
    pub fn state_load(&mut self, bytes: &[u8]) -> Result<(), CkptError> {
        let mut r = ByteReader::new(bytes);
        let rng = Rng::from_state(r.rng_state()?);
        if r.u16()? as usize != self.items.len() {
            return Err(CkptError::BadPayload("transform count mismatch with run config"));
        }
        for t in self.items.iter_mut() {
            t.state_load(r.blob()?)?;
        }
        r.finish()?;
        self.rng = rng;
        Ok(())
    }
}

/// Zero-valued random erasing (paper's variant): per sample, with
/// probability `p`, zero a rectangle whose area/aspect are drawn from the
/// configured ranges.
pub struct RandomErase {
    pub p: f64,
    pub area: (f64, f64),
    pub aspect: (f64, f64),
}

impl Transform for RandomErase {
    fn name(&self) -> &'static str {
        "random_erase"
    }

    fn apply(&mut self, mut batch: Batch, rng: &mut Rng) -> Batch {
        let dims = batch.x.shape.clone();
        let (b, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
        for i in 0..b {
            if !rng.bool(self.p) {
                continue;
            }
            let area = h as f64 * w as f64 * rng.range_f64(self.area.0, self.area.1);
            let mut aspect = rng.range_f64(self.aspect.0, self.aspect.1);
            // paper: randomly swap (He, We) -> (We, He)
            if rng.bool(0.5) {
                aspect = 1.0 / aspect;
            }
            let he = ((area * aspect).sqrt().round() as usize).clamp(1, h);
            let we = ((area / aspect).sqrt().round() as usize).clamp(1, w);
            let y0 = rng.below_usize(h - he + 1);
            let x0 = rng.below_usize(w - we + 1);
            for ch in 0..c {
                for y in y0..y0 + he {
                    let base = ((i * c + ch) * h + y) * w;
                    // zero value, not random (paper's variant)
                    for x in x0..x0 + we {
                        batch.x.data[base + x] = 0.0;
                    }
                }
            }
        }
        batch
    }
}

/// *Running* mixup (Eqs. 18-19): keeps the previous step's virtual batch
/// and mixes the raw batch against it, extending mixup's regularization
/// across steps.
pub struct RunningMixup {
    pub alpha: f64,
    prev: Option<Batch>,
}

impl RunningMixup {
    pub fn new(alpha: f64) -> Self {
        RunningMixup { alpha, prev: None }
    }
}

impl Transform for RunningMixup {
    fn name(&self) -> &'static str {
        "running_mixup"
    }

    fn apply(&mut self, raw: Batch, rng: &mut Rng) -> Batch {
        let out = match &self.prev {
            None => raw.clone(),
            Some(prev) if prev.x.shape == raw.x.shape => {
                let lam = rng.beta_symmetric(self.alpha) as f32;
                let mut x = raw.x.clone();
                let mut t = raw.t.clone();
                for (o, p) in x.data.iter_mut().zip(prev.x.data.iter()) {
                    *o = lam * *o + (1.0 - lam) * p;
                }
                for (o, p) in t.data.iter_mut().zip(prev.t.data.iter()) {
                    *o = lam * *o + (1.0 - lam) * p;
                }
                Batch { x, t }
            }
            Some(_) => raw.clone(), // shape change (e.g. last partial batch)
        };
        self.prev = Some(out.clone());
        out
    }

    fn state_save(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match &self.prev {
            None => w.u8(0),
            Some(b) => {
                w.u8(1);
                b.state_save(&mut w);
            }
        }
        w.into_inner()
    }

    fn state_load(&mut self, bytes: &[u8]) -> Result<(), CkptError> {
        let mut r = ByteReader::new(bytes);
        self.prev = match r.u8()? {
            0 => None,
            1 => Some(Batch::state_load(&mut r)?),
            _ => return Err(CkptError::BadPayload("bad mixup prev flag")),
        };
        r.finish()
    }
}

/// `k×k` average-pool downsampling — the geometry adapter the loader
/// inserts when a source's image grid is an integer multiple of the
/// model's input grid (e.g. CIFAR-10's 32×32 onto a 16×16 or 8×8 model).
/// Stateless and RNG-free, so prepending it never perturbs the
/// augmentation stream.
pub struct Downsample {
    pub k: usize,
}

impl Downsample {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "downsample factor must be >= 1");
        Downsample { k }
    }
}

impl Transform for Downsample {
    fn name(&self) -> &'static str {
        "downsample"
    }

    fn out_shape(&self, (c, h, w): (usize, usize, usize)) -> (usize, usize, usize) {
        (c, h / self.k, w / self.k)
    }

    fn apply(&mut self, batch: Batch, _rng: &mut Rng) -> Batch {
        if self.k == 1 {
            return batch;
        }
        let dims = batch.x.shape.clone();
        let (b, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
        let (ho, wo) = (h / self.k, w / self.k);
        let inv = 1.0 / (self.k * self.k) as f32;
        let mut out = vec![0.0f32; b * c * ho * wo];
        for i in 0..b {
            for ch in 0..c {
                let src = &batch.x.data[(i * c + ch) * h * w..(i * c + ch + 1) * h * w];
                let dst = &mut out[(i * c + ch) * ho * wo..(i * c + ch + 1) * ho * wo];
                for y in 0..ho {
                    for x in 0..wo {
                        let mut s = 0.0f32;
                        for dy in 0..self.k {
                            for dx in 0..self.k {
                                s += src[(y * self.k + dy) * w + x * self.k + dx];
                            }
                        }
                        dst[y * wo + x] = s * inv;
                    }
                }
            }
        }
        Batch {
            x: crate::runtime::HostTensor::new(vec![b, c, ho, wo], out),
            t: batch.t,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;

    fn ones_batch(b: usize) -> Batch {
        Batch {
            x: HostTensor::new(vec![b, 1, 8, 8], vec![1.0; b * 64]),
            t: {
                let mut t = HostTensor::zeros(vec![b, 4]);
                for i in 0..b {
                    t.data[i * 4] = 1.0;
                }
                t
            },
        }
    }

    #[test]
    fn disabled_is_identity() {
        let mut chain = TransformChain::standard(&AugmentCfg::disabled(), 1);
        assert!(chain.is_empty());
        let b = ones_batch(4);
        let out = chain.apply(b.clone());
        assert_eq!(out.x.data, b.x.data);
        assert_eq!(out.t.data, b.t.data);
    }

    #[test]
    fn erasing_zeroes_a_rectangle() {
        let cfg = AugmentCfg { alpha_mixup: 0.0, erase_p: 1.0, ..Default::default() };
        let mut chain = TransformChain::standard(&cfg, 2);
        let out = chain.apply(ones_batch(8));
        let zeros = out.x.data.iter().filter(|&&v| v == 0.0).count();
        assert!(zeros > 0, "some pixels erased");
        // bounded by max area ratio (plus rounding slack)
        assert!(zeros <= 8 * 64 * 40 / 100, "erased too much: {zeros}");
    }

    #[test]
    fn mixup_produces_convex_labels() {
        let cfg = AugmentCfg { alpha_mixup: 0.4, erase_p: 0.0, ..Default::default() };
        let mut chain = TransformChain::standard(&cfg, 3);
        // first batch: class 0; second: class 1
        let b1 = ones_batch(2);
        let mut b2 = ones_batch(2);
        for i in 0..2 {
            b2.t.data[i * 4] = 0.0;
            b2.t.data[i * 4 + 1] = 1.0;
        }
        chain.apply(b1);
        let out = chain.apply(b2);
        for i in 0..2 {
            let row = &out.t.data[i * 4..(i + 1) * 4];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-5, "labels stay a distribution");
            assert!(row[0] >= 0.0 && row[1] >= 0.0);
        }
    }

    #[test]
    fn running_mixup_chains_history() {
        // after two steps, the virtual batch contains traces of step-1
        // inputs (running variant vs vanilla): feed constant 0 images then
        // constant 1; the second output is strictly between unless λ=1
        let cfg = AugmentCfg { alpha_mixup: 10.0, erase_p: 0.0, ..Default::default() };
        let mut chain = TransformChain::standard(&cfg, 4);
        let mut zeros = ones_batch(1);
        zeros.x.data.iter_mut().for_each(|v| *v = 0.0);
        chain.apply(zeros);
        let out = chain.apply(ones_batch(1));
        let m: f32 = out.x.data.iter().sum::<f32>() / 64.0;
        assert!(m > 0.05 && m < 0.999, "mixed value {m}");
    }

    #[test]
    fn downsample_average_pools_and_maps_shape() {
        let mut ds = Downsample::new(2);
        assert_eq!(ds.out_shape((3, 8, 8)), (3, 4, 4));
        // a 4x4 checkerboard of 0/2 average-pools to all-ones at k=2
        let mut x = vec![0.0f32; 16];
        for y in 0..4 {
            for xx in 0..4 {
                if (y + xx) % 2 == 0 {
                    x[y * 4 + xx] = 2.0;
                }
            }
        }
        let b = Batch {
            x: HostTensor::new(vec![1, 1, 4, 4], x),
            t: HostTensor::new(vec![1, 1], vec![1.0]),
        };
        let mut rng = Rng::new(0);
        let out = ds.apply(b, &mut rng);
        assert_eq!(out.x.shape, vec![1, 1, 2, 2]);
        assert!(out.x.data.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn chain_state_roundtrip_resumes_stream() {
        // a restored chain (fresh construction + state_load) must produce
        // the same batches as the original continuing uninterrupted —
        // the loader-cursor half of bit-exact resume
        let cfg = AugmentCfg::default();
        let mut a = TransformChain::standard(&cfg, 9);
        a.apply(ones_batch(2));
        a.apply(ones_batch(2));
        let snap = a.state_save();
        let mut b = TransformChain::standard(&cfg, 9);
        b.state_load(&snap).unwrap();
        for _ in 0..3 {
            let oa = a.apply(ones_batch(2));
            let ob = b.apply(ones_batch(2));
            assert_eq!(oa.x.data, ob.x.data);
            assert_eq!(oa.t.data, ob.t.data);
        }
        // structural mismatch is a hard error, not silent drift
        let mut c = TransformChain::new(9);
        assert!(c.state_load(&snap).is_err());
    }

    #[test]
    fn chain_out_shape_composes() {
        let mut chain = TransformChain::new(1);
        chain.push(Box::new(Downsample::new(2)));
        chain.push(Box::new(Downsample::new(2)));
        assert_eq!(chain.out_shape((3, 32, 32)), (3, 8, 8));
    }
}
