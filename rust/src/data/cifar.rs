//! Disk-backed CIFAR-10-binary-format reader — the repo's first
//! real-file workload.
//!
//! Format (the canonical `data_batch_*.bin` layout): a flat stream of
//! fixed-size records, each `1 + 3*32*32` bytes — one label byte
//! (`0..=9`) followed by the red, green and blue planes row-major. The
//! whole file is loaded into memory at `open` (a full CIFAR-10 batch file
//! is ~30 MB); decoding to f32 happens per sample, on the loader's prep
//! path, so it lands in the prefetch overlap window like every other
//! per-sample cost.
//!
//! Pixels are mapped `byte/127.5 - 1` into `[-1, 1]` (zero-centered, the
//! same scale regime as the synthetic corpus). 32×32 sources train
//! smaller models through the loader's automatic average-pool
//! downsampling (see `data::Loader`).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::data::source::{DataSource, DataSpec};
use crate::util::rng::Rng;

pub const CIFAR_CLASSES: usize = 10;
pub const CIFAR_CHANNELS: usize = 3;
pub const CIFAR_DIM: usize = 32;
/// Bytes per record: 1 label byte + the 3×32×32 image.
pub const CIFAR_RECORD: usize = 1 + CIFAR_CHANNELS * CIFAR_DIM * CIFAR_DIM;

pub struct CifarBin {
    /// raw records, validated at load
    data: Vec<u8>,
    n: usize,
}

impl CifarBin {
    /// Load a CIFAR-10 binary file. Fails on truncated files, empty
    /// files, or out-of-range label bytes.
    pub fn open(path: &Path) -> Result<Self> {
        let data = std::fs::read(path)
            .with_context(|| format!("reading CIFAR-10 binary file {}", path.display()))?;
        Self::from_bytes(data).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse an in-memory CIFAR-10 binary image (the `open` body, split
    /// for round-trip tests).
    pub fn from_bytes(data: Vec<u8>) -> Result<Self> {
        ensure!(!data.is_empty(), "CIFAR-10 file is empty");
        if data.len() % CIFAR_RECORD != 0 {
            bail!(
                "CIFAR-10 file is {} bytes — not a multiple of the {CIFAR_RECORD}-byte record",
                data.len()
            );
        }
        let n = data.len() / CIFAR_RECORD;
        for i in 0..n {
            let label = data[i * CIFAR_RECORD];
            ensure!(
                (label as usize) < CIFAR_CLASSES,
                "record {i}: label byte {label} out of range (0..{CIFAR_CLASSES})"
            );
        }
        Ok(CifarBin { data, n })
    }

    /// Serialize `(label, pixels)` records into the binary format — the
    /// inverse of [`CifarBin::from_bytes`], used to build fixtures and in
    /// the round-trip test.
    pub fn write_records(path: &Path, records: &[(u8, Vec<u8>)]) -> Result<()> {
        let mut out = Vec::with_capacity(records.len() * CIFAR_RECORD);
        for (i, (label, px)) in records.iter().enumerate() {
            ensure!((*label as usize) < CIFAR_CLASSES, "record {i}: label {label} out of range");
            ensure!(
                px.len() == CIFAR_RECORD - 1,
                "record {i}: {} pixel bytes, expected {}",
                px.len(),
                CIFAR_RECORD - 1
            );
            out.push(*label);
            out.extend_from_slice(px);
        }
        std::fs::write(path, out).with_context(|| format!("writing {}", path.display()))
    }

    /// Decode one record's raw bytes (label, pixel plane) — for tests.
    pub fn record_bytes(&self, index: usize) -> (u8, &[u8]) {
        let off = (index % self.n) * CIFAR_RECORD;
        (self.data[off], &self.data[off + 1..off + CIFAR_RECORD])
    }
}

impl DataSource for CifarBin {
    fn name(&self) -> &'static str {
        "cifar10"
    }

    fn spec(&self) -> DataSpec {
        DataSpec {
            classes: CIFAR_CLASSES,
            channels: CIFAR_CHANNELS,
            h: CIFAR_DIM,
            w: CIFAR_DIM,
            len: self.n,
        }
    }

    fn sample(&self, index: usize, _rng: &mut Rng) -> (Vec<f32>, usize) {
        let (label, px) = self.record_bytes(index);
        let img = px.iter().map(|&b| b as f32 / 127.5 - 1.0).collect();
        (img, label as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_malformed_files() {
        assert!(CifarBin::from_bytes(vec![]).is_err());
        assert!(CifarBin::from_bytes(vec![0u8; CIFAR_RECORD - 1]).is_err());
        let mut bad_label = vec![0u8; CIFAR_RECORD];
        bad_label[0] = 10;
        assert!(CifarBin::from_bytes(bad_label).is_err());
    }

    #[test]
    fn decodes_labels_and_normalizes_pixels() {
        let mut rec = vec![0u8; CIFAR_RECORD * 2];
        rec[0] = 3;
        rec[1] = 255; // first red pixel of record 0
        rec[CIFAR_RECORD] = 7;
        let d = CifarBin::from_bytes(rec).unwrap();
        assert_eq!(d.spec().len, 2);
        let mut rng = Rng::new(0);
        let (img, label) = d.sample(0, &mut rng);
        assert_eq!(label, 3);
        assert!((img[0] - 1.0).abs() < 1e-6);
        assert!((img[1] + 1.0).abs() < 1e-6);
        let (_, label1) = d.sample(1, &mut rng);
        assert_eq!(label1, 7);
        // index wraps modulo len
        let (_, label2) = d.sample(2, &mut rng);
        assert_eq!(label2, 3);
    }
}
