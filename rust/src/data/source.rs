//! The [`DataSource`] trait — the sample-level contract every workload
//! implements (the data-axis analog of `optim::Preconditioner`).
//!
//! ## Determinism contract
//!
//! A source is *sample-addressable*: [`DataSource::sample`] must be a pure
//! function of `(index, rng state)` — no interior mutability, no I/O on
//! the sample path (disk-backed sources decode from memory). The
//! [`Loader`](crate::data::Loader) draws every sample of the global batch
//! from **one** data RNG in canonical lane order `g = m·W + w`, handing
//! the stream to `sample` in that order; sources that need per-sample
//! randomness (e.g. the synthetic generator's shift + pixel noise) consume
//! it from the passed stream, deterministic sources ignore it. Because the
//! stream is single and lane-canonical, the synthesized global batch is
//! bit-identical for every worker count that factorizes the same lane
//! total — the invariance `tests/dist_engine.rs` asserts.

use crate::runtime::HostTensor;
use crate::util::rng::Rng;

/// One host-side mini-batch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (B, C, H, W)
    pub x: HostTensor,
    /// (B, K) soft labels
    pub t: HostTensor,
}

impl Batch {
    /// Checkpoint encoding (x then t, bitwise f32).
    pub fn state_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.tensor(&self.x);
        w.tensor(&self.t);
    }

    pub fn state_load(
        r: &mut crate::ckpt::ByteReader,
    ) -> Result<Batch, crate::ckpt::CkptError> {
        Ok(Batch { x: r.tensor()?, t: r.tensor()? })
    }
}

/// Static geometry of a data source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DataSpec {
    pub classes: usize,
    pub channels: usize,
    pub h: usize,
    pub w: usize,
    /// corpus size (sample indices are drawn uniformly from `0..len`)
    pub len: usize,
}

impl DataSpec {
    /// (C, H, W) image geometry.
    pub fn shape(&self) -> (usize, usize, usize) {
        (self.channels, self.h, self.w)
    }
}

/// A deterministic, sample-addressable corpus.
pub trait DataSource: Send + Sync {
    /// Registry-style name (`synth` | `tensor` | `cifar10` | ...).
    fn name(&self) -> &'static str;

    fn spec(&self) -> DataSpec;

    /// The sample at `index` as a `(C*H*W)` image and its class label.
    /// Must be a pure function of `(index, rng state)` — see the module
    /// docs for the determinism contract.
    fn sample(&self, index: usize, rng: &mut Rng) -> (Vec<f32>, usize);
}

/// Draw a batch of `b` samples in the canonical stream order: for each
/// sample, one `below_usize(len)` index draw followed by the source's own
/// consumption. This is the single sampling path shared by the training
/// and validation streams (a bit-exact port of the pre-refactor
/// `SynthDataset::batch`).
pub fn draw_batch(source: &dyn DataSource, b: usize, rng: &mut Rng) -> Batch {
    let spec = source.spec();
    let (c, h, w, k) = (spec.channels, spec.h, spec.w, spec.classes);
    let mut x = vec![0.0f32; b * c * h * w];
    let mut t = vec![0.0f32; b * k];
    for i in 0..b {
        let idx = rng.below_usize(spec.len);
        let (img, class) = source.sample(idx, rng);
        x[i * c * h * w..(i + 1) * c * h * w].copy_from_slice(&img);
        t[i * k + class] = 1.0;
    }
    Batch { x: HostTensor::new(vec![b, c, h, w], x), t: HostTensor::new(vec![b, k], t) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDataset;

    #[test]
    fn draw_batch_matches_synth_batch_bitwise() {
        // the free-function draw path must reproduce the legacy
        // SynthDataset::batch stream bit-for-bit
        let d = SynthDataset::new(10, 3, 8, 8, 500, 42);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let a = d.batch(6, &mut r1);
        let b = draw_batch(&d, 6, &mut r2);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.t.data, b.t.data);
        // and the streams stay aligned across repeated draws
        let a2 = d.batch(6, &mut r1);
        let b2 = draw_batch(&d, 6, &mut r2);
        assert_eq!(a2.x.data, b2.x.data);
    }

    #[test]
    fn labels_are_one_hot() {
        let d = SynthDataset::new(4, 1, 4, 4, 64, 1);
        let mut rng = Rng::new(2);
        let b = draw_batch(&d, 8, &mut rng);
        for i in 0..8 {
            let row = &b.t.data[i * 4..(i + 1) * 4];
            assert_eq!(row.iter().filter(|&&v| v == 1.0).count(), 1);
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
    }
}
