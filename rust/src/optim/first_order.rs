//! First-order optimizers on the [`Preconditioner`] API: the SGD
//! baseline (identity preconditioner) and LARS (layer-wise adaptive rate
//! scaling, You et al. 2017) — the highly-tuned large-batch first-order
//! family the paper compares SP-NGD against. Neither publishes
//! statistics, so the collectives move zero statistic bytes.

use anyhow::Result;

use crate::optim::precond::{LayerStateBox, Preconditioner};
use crate::optim::schedule::HyperParams;
use crate::runtime::{Executor, HostTensor, ModelManifest};

/// SGD with momentum: direction = raw lane-mean gradient.
#[derive(Clone, Copy, Debug, Default)]
pub struct Sgd;

impl Preconditioner for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn default_hparams(&self) -> HyperParams {
        HyperParams {
            alpha_mixup: 0.0,
            p_decay: 3.5,
            e_start: 2.0,
            e_end: 60.0,
            eta0: 0.05,
            m0: 0.045,
            lambda: 2.5e-3,
        }
    }

    fn init_layer(&self, _model: &ModelManifest, _li: usize) -> LayerStateBox {
        Box::new(())
    }

    fn direction(
        &self,
        _engine: &dyn Executor,
        _model: &ModelManifest,
        _li: usize,
        _state: &LayerStateBox,
        grads: &[HostTensor],
        _weights: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        Ok(grads.to_vec())
    }
}

/// LARS (You et al., *Large Batch Training of Convolutional Networks*):
/// per-layer trust ratio
///
/// ```text
/// λ_l = trust_coefficient · ‖w_l‖ / (‖∇L_l‖ + wd·‖w_l‖ + ε)
/// dir  = λ_l · (∇L_l + wd·w_l)
/// ```
///
/// so every layer moves a fixed *relative* amount per step regardless of
/// its gradient scale — the adaptation that makes first-order large-batch
/// training stable. BatchNorm γ/β are excluded from the adaptation (the
/// standard LARS formulation) and take the raw gradient.
///
/// ‖dir‖ ≤ trust_coefficient·‖w‖ by construction, so the update is
/// self-bounding even for vanishing gradients.
#[derive(Clone, Copy, Debug)]
pub struct Lars {
    /// trust coefficient (relative per-step movement at λ_l·η = η)
    pub trust_coefficient: f32,
    /// decoupled L2 term folded into the trust denominator and direction
    pub weight_decay: f32,
    /// numerical floor for the trust denominator
    pub eps: f32,
}

impl Default for Lars {
    fn default() -> Self {
        Lars { trust_coefficient: 1.0, weight_decay: 0.0, eps: 1e-9 }
    }
}

impl Preconditioner for Lars {
    fn name(&self) -> &'static str {
        "lars"
    }

    fn default_hparams(&self) -> HyperParams {
        // with trust_coefficient 1, η is the relative per-step movement:
        // 2% of each layer's norm per step, momentum-coupled like the rest
        HyperParams {
            alpha_mixup: 0.0,
            p_decay: 3.5,
            e_start: 2.0,
            e_end: 60.0,
            eta0: 0.02,
            m0: 0.018,
            lambda: 2.5e-3,
        }
    }

    fn init_layer(&self, _model: &ModelManifest, _li: usize) -> LayerStateBox {
        Box::new(())
    }

    fn direction(
        &self,
        _engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        _state: &LayerStateBox,
        grads: &[HostTensor],
        weights: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let ml = &model.kfac_layers[li];
        if ml.is_bn() {
            // BN parameters are excluded from layer-wise adaptation
            return Ok(grads.to_vec());
        }
        let mut dirs = Vec::with_capacity(grads.len());
        for (g, w) in grads.iter().zip(weights.iter()) {
            // λ_l from the *raw* gradient norm (wd enters the denominator
            // exactly once), applied to the decayed direction g + wd·w
            let wn = w.norm();
            let gn = g.norm();
            let trust =
                self.trust_coefficient * wn / (gn + self.weight_decay * wn + self.eps);
            let mut d = g.clone();
            if self.weight_decay > 0.0 {
                d.axpy_inplace(self.weight_decay, w);
            }
            d.scale_inplace(trust);
            dirs.push(d);
        }
        Ok(dirs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ht(data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::new(vec![n], data)
    }

    #[test]
    fn lars_direction_norm_is_trust_bounded() {
        // ‖dir‖ = tc·‖w‖·‖g‖/(‖g‖+ε) ≤ tc·‖w‖, and ≈ tc·‖w‖ for healthy g
        let lars = Lars::default();
        let w = ht(vec![3.0, 4.0]); // ‖w‖ = 5
        for scale in [1e-6f32, 1.0, 1e6] {
            let g = ht(vec![scale, 0.0]);
            let wn = w.norm();
            let gn = g.norm();
            let trust = lars.trust_coefficient * wn / (gn + lars.eps);
            let dir_norm = trust * gn;
            assert!(dir_norm <= lars.trust_coefficient * wn * 1.0001, "scale {scale}");
            if scale >= 1.0 {
                assert!(dir_norm > 0.99 * lars.trust_coefficient * wn, "scale {scale}");
            }
        }
    }

    #[test]
    fn per_optimizer_default_hparams() {
        // the harness satellite: η₀/m₀ defaults live with each optimizer
        // instead of being special-cased at call sites
        assert_eq!(Sgd.default_hparams().eta0, 0.05);
        assert_eq!(Sgd.default_hparams().m0, 0.045);
        assert_eq!(Lars::default().default_hparams().eta0, 0.02);
        assert_eq!(crate::optim::SpNgd::default().default_hparams().eta0, 0.02);
    }
}
