//! SP-NGD as a [`Preconditioner`]: K-FAC factors with π-split damping,
//! unit-wise/full BatchNorm Fisher, and the adaptive stale-statistics
//! scheduler — the paper's optimizer, ported onto the composable API
//! bit-identically to the pre-refactor trainer path (asserted by
//! `tests/optim_api.rs`).

use anyhow::{Context, Result};

use crate::ckpt::{ByteReader, ByteWriter, CkptError};
use crate::kfac::bn::{BnFisher, BnFullFisher};
use crate::kfac::damping::pi_split;
use crate::linalg::Mat;
use crate::optim::precond::{BnMode, Fisher, LayerStateBox, Preconditioner, StatKind};
use crate::optim::schedule::HyperParams;
use crate::optim::stale::StaleState;
use crate::runtime::{Executor, HostTensor, ModelManifest};

/// SP-NGD configuration — what used to be the NGD half of `TrainerCfg`.
#[derive(Clone, Debug)]
pub struct SpNgd {
    /// Fisher estimation mode (§4.1)
    pub fisher: Fisher,
    /// BatchNorm Fisher mode (§4.2)
    pub bn_mode: BnMode,
    /// adaptive stale-statistics scheduler (§4.3); false = refresh every step
    pub stale: bool,
    /// similarity threshold α (paper: 0.1)
    pub stale_alpha: f32,
    /// base damping λ
    pub lambda: f32,
}

impl Default for SpNgd {
    fn default() -> Self {
        SpNgd {
            fisher: Fisher::Emp,
            bn_mode: BnMode::Unit,
            stale: false,
            stale_alpha: 0.1,
            lambda: 2.5e-3,
        }
    }
}

/// Per-layer SP-NGD state: the stale schedulers, the owner's factor
/// cache, and the damped inverses.
pub struct SpNgdLayer {
    pub a_stale: StaleState,
    pub g_stale: StaleState,
    /// current reduced factors (owner's copy)
    a: Option<Mat>,
    g: Option<Mat>,
    /// cached damped inverses (padded-bucket sliced back)
    a_inv: Option<HostTensor>,
    g_inv: Option<HostTensor>,
    /// BN state
    bn_fisher: Option<BnFisher>,
    bn_full_inv: Option<Mat>,
}

impl SpNgdLayer {
    fn new(alpha: f32) -> Self {
        SpNgdLayer {
            a_stale: StaleState::new(alpha),
            g_stale: StaleState::new(alpha),
            a: None,
            g: None,
            a_inv: None,
            g_inv: None,
            bn_fisher: None,
            bn_full_inv: None,
        }
    }
}

/// Layer-state payload version (inside the opaque SEC_LAYER blob).
const LAYER_STATE_V: u8 = 1;

fn save_stale(w: &mut ByteWriter, st: &StaleState) {
    w.f32(st.alpha);
    w.u64(st.next_refresh);
    w.u64(st.delta);
    w.u64(st.delta_prev);
    w.u64(st.refreshes);
    w.u64(st.skips);
    let (last, before_last) = st.history();
    w.opt_mat(last);
    w.opt_mat(before_last);
}

fn load_stale(r: &mut ByteReader) -> Result<StaleState, CkptError> {
    let mut st = StaleState::new(r.f32()?);
    st.next_refresh = r.u64()?;
    st.delta = r.u64()?;
    st.delta_prev = r.u64()?;
    st.refreshes = r.u64()?;
    st.skips = r.u64()?;
    let last = r.opt_mat()?;
    let before_last = r.opt_mat()?;
    st.set_history(last, before_last);
    Ok(st)
}

fn layer_state(state: &LayerStateBox) -> Result<&SpNgdLayer> {
    state.downcast_ref::<SpNgdLayer>().context("layer state is not SpNgdLayer")
}

fn layer_state_mut(state: &mut LayerStateBox) -> Result<&mut SpNgdLayer> {
    state.downcast_mut::<SpNgdLayer>().context("layer state is not SpNgdLayer")
}

/// π split from cached traces (both factors' traces are known even when
/// only one refreshed this step).
fn pi_split_traces(tr_a: f32, dim_a: f32, tr_g: f32, dim_g: f32, lambda: f32) -> (f32, f32) {
    let a = Mat::from_vec(1, 1, vec![tr_a / dim_a.max(1.0)]);
    let g = Mat::from_vec(1, 1, vec![tr_g / dim_g.max(1.0)]);
    pi_split(&a, &g, lambda)
}

impl Preconditioner for SpNgd {
    fn name(&self) -> &'static str {
        "spngd"
    }

    fn fisher(&self) -> Fisher {
        self.fisher
    }

    fn default_hparams(&self) -> HyperParams {
        HyperParams {
            alpha_mixup: 0.0,
            p_decay: 3.5,
            e_start: 2.0,
            e_end: 60.0,
            eta0: 0.02,
            m0: 0.018,
            lambda: 2.5e-3,
        }
    }

    fn init_layer(&self, _model: &ModelManifest, _li: usize) -> LayerStateBox {
        Box::new(SpNgdLayer::new(self.stale_alpha))
    }

    fn stats_spec(&self, model: &ModelManifest, li: usize) -> Vec<StatKind> {
        if model.kfac_layers[li].is_bn() {
            vec![StatKind::BnF]
        } else {
            vec![StatKind::A, StatKind::G]
        }
    }

    fn stat_shape(&self, model: &ModelManifest, li: usize, kind: StatKind) -> (usize, usize) {
        let ml = &model.kfac_layers[li];
        match kind {
            StatKind::A => (ml.a_dim, ml.a_dim),
            StatKind::G => (ml.g_dim, ml.g_dim),
            StatKind::BnF => match self.bn_mode {
                BnMode::Unit => (ml.channels, 3),
                BnMode::Full => (2 * ml.channels, 2 * ml.channels),
            },
        }
    }

    /// Alg. 1's per-statistic schedule: everything is due when the stale
    /// scheduler is off; otherwise each statistic consults its own
    /// interval (and records skips for the reduction metric).
    fn plan(
        &self,
        model: &ModelManifest,
        li: usize,
        state: &mut LayerStateBox,
        t: u64,
    ) -> Vec<StatKind> {
        let st = layer_state_mut(state).expect("spngd layer state");
        let due_always = !self.stale;
        let mut due = Vec::new();
        if model.kfac_layers[li].is_bn() {
            if due_always || st.a_stale.due(t) {
                due.push(StatKind::BnF);
            } else {
                st.a_stale.note_skip();
            }
        } else {
            if due_always || st.a_stale.due(t) {
                due.push(StatKind::A);
            } else {
                st.a_stale.note_skip();
            }
            if due_always || st.g_stale.due(t) {
                due.push(StatKind::G);
            } else {
                st.g_stale.note_skip();
            }
        }
        due
    }

    /// Stage 1-2: one statistic from the step executable's taps (SYRK
    /// factor products; unit-BN blocks are built host-side).
    fn build_stat(
        &self,
        engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        kind: StatKind,
        outs: &[HostTensor],
    ) -> Result<Mat> {
        let ml = &model.kfac_layers[li];
        let mat = match kind {
            StatKind::A => {
                let ti = model
                    .output_index("a_tap", Some(&ml.name))
                    .context("a_tap index")?;
                let f = engine.execute(&ml.factor_a, &[&outs[ti]])?;
                f[0].as_mat()
            }
            StatKind::G => {
                let ti = model
                    .output_index("g_tap", Some(&ml.name))
                    .context("g_tap index")?;
                let tap = &outs[ti];
                let f = if ml.kind == "conv" {
                    let t2 = tap.nchw_to_rows_channels();
                    engine.execute(&ml.factor_g, &[&t2])?
                } else {
                    engine.execute(&ml.factor_g, &[tap])?
                };
                f[0].as_mat()
            }
            StatKind::BnF => {
                let gi = model
                    .output_index("g_gamma", Some(&ml.name))
                    .context("g_gamma index")?;
                let bi = model
                    .output_index("g_beta", Some(&ml.name))
                    .context("g_beta index")?;
                match self.bn_mode {
                    BnMode::Unit => BnFisher::from_taps(
                        &outs[gi].data,
                        &outs[bi].data,
                        model.batch,
                        ml.channels,
                    )
                    .as_mat(),
                    BnMode::Full => {
                        let f = engine.execute(&ml.bn_full, &[&outs[gi], &outs[bi]])?;
                        f[0].as_mat()
                    }
                }
            }
        };
        Ok(mat)
    }

    /// Stage 4a: Alg. 2 scheduler refresh, owner factor-cache update,
    /// then damped inversion of the freshly reduced statistics (π-split
    /// damping from the cached traces).
    fn refresh(
        &self,
        engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        state: &mut LayerStateBox,
        t: u64,
        items: Vec<(StatKind, Mat)>,
    ) -> Result<()> {
        let layer = layer_state_mut(state)?;
        let ml = &model.kfac_layers[li];
        for (kind, m) in &items {
            match kind {
                StatKind::A => {
                    layer.a_stale.refresh(t, m);
                    layer.a = Some(m.clone());
                }
                StatKind::G => {
                    layer.g_stale.refresh(t, m);
                    layer.g = Some(m.clone());
                }
                StatKind::BnF => {
                    layer.a_stale.refresh(t, m);
                }
            }
        }
        // traces for the π split (both factors' traces are known even when
        // only one refreshed this step)
        let tr_a = layer.a.as_ref().map(|m| m.trace()).unwrap_or(0.0);
        let tr_g = layer.g.as_ref().map(|m| m.trace()).unwrap_or(0.0);
        for (kind, mat) in items {
            match kind {
                StatKind::BnF if self.bn_mode == BnMode::Unit => {
                    // closed-form per-channel blocks — nothing to invert
                    layer.bn_fisher = Some(BnFisher {
                        channels: ml.channels,
                        blocks: (0..ml.channels)
                            .map(|c| [mat.data[c * 3], mat.data[c * 3 + 1], mat.data[c * 3 + 2]])
                            .collect(),
                    });
                }
                StatKind::BnF => {
                    let padded = HostTensor::from_mat(&mat).pad_square(ml.full_bucket);
                    let damp = HostTensor::scalar(self.lambda);
                    let out = engine.execute(&ml.invert_full, &[&padded, &damp])?;
                    let inv = out[0].slice_square(2 * ml.channels);
                    layer.bn_full_inv = Some(inv.as_mat());
                }
                StatKind::A | StatKind::G => {
                    let (da, dg) =
                        pi_split_traces(tr_a, ml.a_dim as f32, tr_g, ml.g_dim as f32, self.lambda);
                    let (exe, bucket, dim, damp) = match kind {
                        StatKind::A => (&ml.invert_a, ml.a_bucket, ml.a_dim, da),
                        _ => (&ml.invert_g, ml.g_bucket, ml.g_dim, dg),
                    };
                    let padded = HostTensor::from_mat(&mat).pad_square(bucket);
                    let damp = HostTensor::scalar(damp);
                    let out = engine.execute(exe, &[&padded, &damp])?;
                    let inv = out[0].slice_square(dim);
                    match kind {
                        StatKind::A => layer.a_inv = Some(inv),
                        _ => layer.g_inv = Some(inv),
                    }
                }
            }
        }
        Ok(())
    }

    /// Stage 4b: (F̂+λI)⁻¹∇L through the cached Kronecker-factor inverses
    /// (the `precond` executable) or the BN Fisher blocks.
    fn direction(
        &self,
        engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        state: &LayerStateBox,
        grads: &[HostTensor],
        _weights: &[&HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let layer = layer_state(state)?;
        let ml = &model.kfac_layers[li];
        if ml.is_bn() {
            let g_gamma = &grads[0];
            let g_beta = &grads[1];
            let (dir_g, dir_b) = match self.bn_mode {
                BnMode::Unit => {
                    let f = layer.bn_fisher.as_ref().context("bn fisher missing")?;
                    f.precondition(&g_gamma.data, &g_beta.data, self.lambda)
                }
                BnMode::Full => {
                    let inv = layer.bn_full_inv.as_ref().context("bn full inverse missing")?;
                    BnFullFisher::apply_inverse(inv, &g_gamma.data, &g_beta.data)
                }
            };
            Ok(vec![
                HostTensor::new(g_gamma.shape.clone(), dir_g),
                HostTensor::new(g_beta.shape.clone(), dir_b),
            ])
        } else {
            let gw = &grads[0];
            let (m, n) = ml.grad_shape;
            let gmat = gw.clone().reshape(vec![m, n]);
            let ainv = layer.a_inv.as_ref().context("A inverse missing")?;
            let ginv = layer.g_inv.as_ref().context("G inverse missing")?;
            let out = engine.execute(&ml.precond, &[ginv, &gmat, ainv])?;
            Ok(vec![out[0].clone().reshape(gw.shape.clone())])
        }
    }

    /// Full per-layer snapshot: factor caches, damped inverses, BN
    /// Fisher, and both stale schedulers (history matrices included, so
    /// the Fibonacci interval evolution resumes bit-exactly).
    fn state_save(&self, _model: &ModelManifest, _li: usize, state: &LayerStateBox) -> Vec<u8> {
        let layer = layer_state(state).expect("spngd layer state");
        let mut w = ByteWriter::new();
        w.u8(LAYER_STATE_V);
        save_stale(&mut w, &layer.a_stale);
        save_stale(&mut w, &layer.g_stale);
        w.opt_mat(layer.a.as_ref());
        w.opt_mat(layer.g.as_ref());
        w.opt_tensor(layer.a_inv.as_ref());
        w.opt_tensor(layer.g_inv.as_ref());
        match &layer.bn_fisher {
            None => w.u8(0),
            Some(f) => {
                w.u8(1);
                w.u32(f.channels as u32);
                for b in &f.blocks {
                    w.f32s(b);
                }
            }
        }
        w.opt_mat(layer.bn_full_inv.as_ref());
        w.into_inner()
    }

    fn state_load(
        &self,
        _model: &ModelManifest,
        _li: usize,
        state: &mut LayerStateBox,
        bytes: &[u8],
    ) -> Result<()> {
        let layer = layer_state_mut(state)?;
        let mut r = ByteReader::new(bytes);
        let v = r.u8()?;
        anyhow::ensure!(v == LAYER_STATE_V, "spngd layer-state version {v} unsupported");
        layer.a_stale = load_stale(&mut r)?;
        layer.g_stale = load_stale(&mut r)?;
        layer.a = r.opt_mat()?;
        layer.g = r.opt_mat()?;
        layer.a_inv = r.opt_tensor()?;
        layer.g_inv = r.opt_tensor()?;
        layer.bn_fisher = match r.u8()? {
            0 => None,
            1 => {
                let channels = r.u32()? as usize;
                let mut blocks = Vec::with_capacity(channels.min(1 << 16));
                for _ in 0..channels {
                    let b = r.f32s(3)?;
                    blocks.push([b[0], b[1], b[2]]);
                }
                Some(BnFisher { channels, blocks })
            }
            _ => anyhow::bail!("spngd layer state: bad bn_fisher flag"),
        };
        layer.bn_full_inv = r.opt_mat()?;
        r.finish()?;
        Ok(())
    }

    fn refresh_fractions(
        &self,
        model: &ModelManifest,
        li: usize,
        state: &LayerStateBox,
    ) -> Vec<f64> {
        let st = state.downcast_ref::<SpNgdLayer>().expect("spngd layer state");
        if model.kfac_layers[li].is_bn() {
            // BN layers track their single statistic on the A slot
            vec![st.a_stale.refresh_fraction()]
        } else {
            vec![st.a_stale.refresh_fraction(), st.g_stale.refresh_fraction()]
        }
    }
}
