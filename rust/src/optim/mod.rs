//! The composable optimizer API (§4, §6.2-6.3).
//!
//! An optimizer is three orthogonal pieces, each swappable:
//!
//! - [`Preconditioner`] — per-layer second-order state and the
//!   gradient→direction map, split along the trainer's Stage boundaries
//!   (`stats_spec`/`plan` → `build_stat` → `refresh` → `direction`);
//!   implementations: [`SpNgd`] (the paper), [`Sgd`], [`Lars`].
//! - [`UpdateRule`] — how a direction hits the weights (trust-ratio
//!   clip, Eq. 23 momentum, Normalizing Weights); stock: [`MomentumRule`].
//! - [`SchedulePolicy`] — η(t)/m(t); stock: [`Schedule`] (Eqs. 21-22).
//!
//! `coordinator::TrainerBuilder` composes the three with a model and a
//! dist engine. The [`registry`] maps `--optim` names to
//! preconditioners; unknown names are a hard error.

pub mod first_order;
pub mod precond;
pub mod registry;
pub mod schedule;
pub mod spngd;
pub mod stale;
pub mod update;

pub use first_order::{Lars, Sgd};
pub use precond::{
    apply_layer_update, grad_tensor, stat_elems, BnMode, Fisher, LayerStateBox, ParamSlot,
    Preconditioner, StatKind,
};
pub use registry::{by_name, lars, sgd, spngd, OPTIMIZER_NAMES};
pub use schedule::{HyperParams, Schedule, SchedulePolicy};
pub use spngd::{SpNgd, SpNgdLayer};
pub use stale::StaleState;
pub use update::{
    clip_direction, rescale_weight, sgd_update, spngd_update, MomentumRule, ParamCtx, UpdateRule,
    Velocity,
};
