//! Optimizer schedules and update rules (§6.2-6.3).

pub mod schedule;
pub mod update;

pub use schedule::{HyperParams, Schedule};
pub use update::{sgd_update, spngd_update, rescale_weight, Velocity};
