//! The composable optimizer API: [`Preconditioner`] owns the per-layer
//! second-order state and splits along the trainer's Stage boundaries.
//!
//! One training step touches an optimizer at four points:
//!
//! ```text
//! plan(t)        coordinator, before the worker fan-out: consult the
//!                per-layer scheduler — which of stats_spec() is due
//! build_stat     Stage 1-2, every lane: construct one planned statistic
//!                from the step executable's taps (published to the
//!                collective the moment it is ready)
//! refresh        Stage 4a, the layer's owner only: fold the reduced
//!                statistics into the layer state (scheduler update,
//!                damping, inversion)
//! direction      Stage 4b, once per layer: turn the lane-mean gradient
//!                into an update direction (the preconditioning)
//! ```
//!
//! The [`UpdateRule`](super::update::UpdateRule) then applies the
//! direction to the weights (trust-ratio clip, momentum, Normalizing
//! Weights), and a [`SchedulePolicy`](super::schedule::SchedulePolicy)
//! supplies η(t)/m(t). Both dist engines (sequential coordinator and the
//! threaded `dist` workers) drive the same trait object; per-layer state
//! lives in a [`LayerStateBox`] owned by the layer's Stage-4 owner, so
//! owner threads mutate disjoint state without locks.
//!
//! First-order optimizers publish no statistics: `stats_spec()` returns
//! an empty vec, `plan`/`refresh` never fire, and the statistics
//! collectives move zero bytes.

use std::any::Any;
use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::collectives::comm::StatClass;
use crate::linalg::Mat;
use crate::optim::schedule::HyperParams;
use crate::optim::update::{ParamCtx, UpdateRule};
use crate::runtime::{Executor, HostTensor, ModelManifest};

/// Fisher estimation mode (§4.1). Selected by the preconditioner
/// ([`Preconditioner::fisher`]) since only NGD-family optimizers care.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fisher {
    /// empirical Fisher captured in the ordinary bwd pass (`emp`)
    Emp,
    /// one-sample Monte-Carlo Fisher — extra backward pass (`1mc`)
    OneMc,
}

/// BatchNorm Fisher mode (§4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BnMode {
    /// unit-wise 2×2 blocks, closed-form inverse (`unitBN`)
    Unit,
    /// full (2C)² Fisher inverted like any factor (`fullBN`)
    Full,
}

/// Which statistic of a layer an entry in the refresh plan tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StatKind {
    /// input-activation factor A
    A,
    /// output-gradient factor G
    G,
    /// BatchNorm Fisher (unit-wise blocks or the full (2C)² matrix)
    BnF,
}

impl StatKind {
    /// Collective accounting class (A vs G/F payload split of Fig. 6).
    pub fn class(self) -> StatClass {
        match self {
            StatKind::A => StatClass::A,
            _ => StatClass::GorF,
        }
    }
}

/// Per-layer optimizer state, owned by the layer's Stage-4 owner. Each
/// preconditioner downcasts to its own concrete type; stateless
/// optimizers store `()`.
pub type LayerStateBox = Box<dyn Any + Send + Sync>;

/// A pluggable optimizer: the per-layer second-order machinery behind
/// one training step. See the module docs for the call protocol; the
/// Stage 4a/4b contract (refresh at most once per layer per step, at the
/// owner; direction exactly once per layer per step) is asserted by
/// `tests/optim_api.rs`'s `MockPreconditioner`.
pub trait Preconditioner: Send + Sync {
    /// Registry name (`--optim` value).
    fn name(&self) -> &'static str;

    /// Which gradient estimator Stage 1 runs (step executable + seeds).
    fn fisher(&self) -> Fisher {
        Fisher::Emp
    }

    /// This optimizer's default hyperparameters for short synthetic-corpus
    /// runs — the harness consults this instead of special-casing η₀/m₀
    /// per optimizer.
    fn default_hparams(&self) -> HyperParams;

    /// Fresh per-layer state (called once per layer at trainer build).
    fn init_layer(&self, model: &ModelManifest, li: usize) -> LayerStateBox;

    /// Which statistics layer `li` publishes on a full refresh step.
    /// Empty (the default) = this optimizer needs no reduced statistics.
    fn stats_spec(&self, model: &ModelManifest, li: usize) -> Vec<StatKind> {
        let _ = (model, li);
        Vec::new()
    }

    /// Reduced-mat shape of one planned statistic — used to keep the
    /// collective protocol alive with zero payloads when a worker errors
    /// mid-step.
    fn stat_shape(&self, model: &ModelManifest, li: usize, kind: StatKind) -> (usize, usize) {
        let ml = &model.kfac_layers[li];
        match kind {
            StatKind::A => (ml.a_dim, ml.a_dim),
            StatKind::G => (ml.g_dim, ml.g_dim),
            StatKind::BnF => (ml.channels, 3),
        }
    }

    /// Coordinator-side scheduler consult (Alg. 1's `t == t_X`): the
    /// subset of [`Preconditioner::stats_spec`] due for refresh at step
    /// `t`. May mutate the layer state (skip counters, intervals).
    fn plan(
        &self,
        model: &ModelManifest,
        li: usize,
        state: &mut LayerStateBox,
        t: u64,
    ) -> Vec<StatKind> {
        let _ = (model, li, state, t);
        Vec::new()
    }

    /// Stage 1-2 on every lane: construct one planned statistic from the
    /// step executable's outputs. Default: a zero payload of
    /// [`Preconditioner::stat_shape`] (useful for mocks).
    fn build_stat(
        &self,
        engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        kind: StatKind,
        outs: &[HostTensor],
    ) -> Result<Mat> {
        let _ = (engine, outs);
        let (r, c) = self.stat_shape(model, li, kind);
        Ok(Mat::zeros(r, c))
    }

    /// Stage 4a at the layer's owner: fold the freshly reduced statistics
    /// into the layer state (scheduler refresh, damping, inversion).
    /// Called at most once per layer per step, only with a non-empty
    /// `items`, only by the owner (which holds the `&mut`).
    fn refresh(
        &self,
        engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        state: &mut LayerStateBox,
        t: u64,
        items: Vec<(StatKind, Mat)>,
    ) -> Result<()> {
        let _ = (engine, model, li, state, t, items);
        Ok(())
    }

    /// Stage 4b, once per layer per step: map the lane-mean gradients of
    /// the layer's parameters (canonical order: `[weight]` or
    /// `[gamma, beta]`) to update directions, one per parameter.
    /// `weights` are the current parameter values (read-only), for
    /// optimizers whose direction depends on them (e.g. LARS).
    fn direction(
        &self,
        engine: &dyn Executor,
        model: &ModelManifest,
        li: usize,
        state: &LayerStateBox,
        grads: &[HostTensor],
        weights: &[&HostTensor],
    ) -> Result<Vec<HostTensor>>;

    /// Serialize layer `li`'s state for a checkpoint. The payload is
    /// opaque to the checkpoint layer; bit-exact resume requires that
    /// `state_load(state_save(x)) == x` for everything `refresh` /
    /// `direction` read. The default (empty payload) is correct for
    /// stateless optimizers (SGD, LARS).
    fn state_save(&self, model: &ModelManifest, li: usize, state: &LayerStateBox) -> Vec<u8> {
        let _ = (model, li, state);
        Vec::new()
    }

    /// Restore layer `li`'s state from a [`Preconditioner::state_save`]
    /// payload. The default accepts only the empty payload it saves.
    fn state_load(
        &self,
        model: &ModelManifest,
        li: usize,
        state: &mut LayerStateBox,
        bytes: &[u8],
    ) -> Result<()> {
        let _ = (model, li, state);
        anyhow::ensure!(
            bytes.is_empty(),
            "{}: unexpected layer-state payload ({} bytes) for a stateless optimizer",
            self.name(),
            bytes.len()
        );
        Ok(())
    }

    /// Per-statistic refresh fractions, one entry per
    /// [`Preconditioner::stats_spec`] item in the same order (the
    /// Table 2 reduction metric). Empty = no statistics, reduction
    /// reported as 1.
    fn refresh_fractions(
        &self,
        model: &ModelManifest,
        li: usize,
        state: &LayerStateBox,
    ) -> Vec<f64> {
        let _ = (model, li, state);
        Vec::new()
    }
}

/// Communicated element count of one statistic (packed symmetric for
/// square factors, 3 per channel for unit-BN blocks) — the weights of
/// the Table-2 comm-reduction metric.
pub fn stat_elems(model: &ModelManifest, li: usize, kind: StatKind) -> usize {
    let ml = &model.kfac_layers[li];
    match kind {
        StatKind::A => ml.a_dim * (ml.a_dim + 1) / 2,
        StatKind::G => ml.g_dim * (ml.g_dim + 1) / 2,
        StatKind::BnF => 3 * ml.channels,
    }
}

/// One parameter's update slot (weight + velocity), partitioned by layer
/// owner so dist workers update disjoint parameters concurrently.
pub struct ParamSlot<'a> {
    pub p: &'a mut HostTensor,
    pub v: &'a mut HostTensor,
}

/// The lane-mean gradient of parameter `pi`, sliced from the flat
/// all-reduced vector.
pub fn grad_tensor(model: &ModelManifest, flat: &[f32], pi: usize) -> HostTensor {
    let mut off = 0usize;
    for p in &model.params[..pi] {
        off += p.shape.iter().product::<usize>();
    }
    let n: usize = model.params[pi].shape.iter().product();
    HostTensor::new(model.params[pi].shape.clone(), flat[off..off + n].to_vec())
}

/// Stage 4b for one layer at its owner: preconditioned directions from
/// the trait object, the numerical guard (a degenerate Fisher — possible
/// when the loss approaches zero — can blow up the inverse; fall back to
/// the raw gradient for this step), then the update rule per parameter
/// in canonical order. The one code path both dist engines run.
#[allow(clippy::too_many_arguments)]
pub fn apply_layer_update(
    engine: &dyn Executor,
    model: &ModelManifest,
    opt: &dyn Preconditioner,
    rule: &dyn UpdateRule,
    li: usize,
    state: &LayerStateBox,
    slots: &mut BTreeMap<usize, ParamSlot>,
    grads_flat: &[f32],
    lr: f32,
    mom: f32,
) -> Result<()> {
    let ml = &model.kfac_layers[li];
    let (pis, ctx) = if ml.is_bn() {
        (
            vec![
                model.param_index(&ml.gamma_param).context("gamma param")?,
                model.param_index(&ml.beta_param).context("beta param")?,
            ],
            ParamCtx { layer_kind: "bn", d_out: ml.channels },
        )
    } else {
        (
            vec![model.param_index(&ml.weight_param).context("weight param")?],
            ParamCtx { layer_kind: ml.kind.as_str(), d_out: ml.grad_shape.0 },
        )
    };
    let grads: Vec<HostTensor> =
        pis.iter().map(|&pi| grad_tensor(model, grads_flat, pi)).collect();
    let mut dirs = {
        let weights: Vec<&HostTensor> = pis
            .iter()
            .map(|&pi| slots.get(&pi).map(|s| &*s.p).context("param slot"))
            .collect::<Result<_>>()?;
        opt.direction(engine, model, li, state, &grads, &weights)?
    };
    anyhow::ensure!(
        dirs.len() == grads.len(),
        "direction() returned {} dirs for {} params (layer {})",
        dirs.len(),
        grads.len(),
        ml.name
    );
    for (i, &pi) in pis.iter().enumerate() {
        let mut dir = std::mem::replace(&mut dirs[i], HostTensor::zeros(vec![0]));
        if !dir.norm().is_finite() {
            dir = grads[i].clone();
        }
        let slot = slots.get_mut(&pi).context("param slot")?;
        rule.apply(slot.p, slot.v, &mut dir, lr, mom, &ctx);
    }
    Ok(())
}
