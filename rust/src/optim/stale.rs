//! Natural gradient with stale statistics — Algorithms 1 & 2 (§4.3).
//!
//! Each statistic X (an A factor, a G factor, or a BN Fisher) carries a
//! scheduler that decides, from the Frobenius-relative drift between
//! successive refreshes, how many steps the current value stays
//! acceptable:
//!
//! ```text
//! if X not similar to X₋₁:            Δ ← max(1, ⌊Δ₋₁/2⌋)   (halve)
//! else if X not similar to X₋₂:       Δ ← Δ₋₁               (hold)
//! else:                               Δ ← Δ₋₁ + Δ₋₂          (Fibonacci growth)
//! ```
//!
//! similar(A, B) ⇔ ‖A − B‖_F / ‖B‖_F < α  (paper: α = 0.1).

use crate::linalg::Mat;

/// Scheduler state for one statistic.
#[derive(Clone, Debug)]
pub struct StaleState {
    /// refresh threshold α
    pub alpha: f32,
    /// next step at which to refresh (t_X in Alg. 1)
    pub next_refresh: u64,
    /// Δ (current interval) and Δ₋₁ (previous interval)
    pub delta: u64,
    pub delta_prev: u64,
    /// X₋₁ and X₋₂ snapshots (set after refreshes)
    last: Option<Mat>,
    before_last: Option<Mat>,
    /// counters for reporting (Table 2 reduction column)
    pub refreshes: u64,
    pub skips: u64,
}

impl StaleState {
    pub fn new(alpha: f32) -> Self {
        StaleState {
            alpha,
            next_refresh: 1,
            delta: 1,
            delta_prev: 1,
            last: None,
            before_last: None,
            refreshes: 0,
            skips: 0,
        }
    }

    /// Does statistic X need refreshing at step `t` (Alg. 1's `t == t_X`)?
    pub fn due(&self, t: u64) -> bool {
        t >= self.next_refresh
    }

    /// Record a skipped step (bookkeeping for the reduction metric).
    pub fn note_skip(&mut self) {
        self.skips += 1;
    }

    /// `similar(A, B)` per the paper: ‖A−B‖_F / ‖B‖_F < α.
    pub fn similar(&self, a: &Mat, b: &Mat) -> bool {
        let denom = b.fro_norm();
        if denom == 0.0 {
            return a.fro_norm() == 0.0;
        }
        a.fro_dist(b) / denom < self.alpha
    }

    /// Feed a freshly-computed statistic (Alg. 2); advances the refresh
    /// schedule and stores history. Returns the new interval Δ.
    pub fn refresh(&mut self, t: u64, x: &Mat) -> u64 {
        self.refreshes += 1;
        let new_delta = match (&self.last, &self.before_last) {
            (Some(x1), _) if !self.similar(x, x1) => (self.delta / 2).max(1),
            (Some(_), Some(x2)) if !self.similar(x, x2) => self.delta,
            (Some(_), Some(_)) => self.delta + self.delta_prev,
            // not enough history yet: stay at 1-step cadence
            _ => 1,
        };
        self.delta_prev = self.delta;
        self.delta = new_delta;
        self.next_refresh = t + new_delta;
        self.before_last = self.last.take();
        self.last = Some(x.clone());
        new_delta
    }

    /// Snapshot the similarity history (X₋₁, X₋₂) for checkpointing.
    pub fn history(&self) -> (Option<&Mat>, Option<&Mat>) {
        (self.last.as_ref(), self.before_last.as_ref())
    }

    /// Restore the similarity history from a checkpoint; together with
    /// the public counters this makes a restored scheduler bit-identical
    /// to one that never stopped.
    pub fn set_history(&mut self, last: Option<Mat>, before_last: Option<Mat>) {
        self.last = last;
        self.before_last = before_last;
    }

    /// Fraction of steps on which this statistic was actually refreshed
    /// (the Table 2 "reduction" metric: lower = more stale reuse).
    pub fn refresh_fraction(&self) -> f64 {
        let total = self.refreshes + self.skips;
        if total == 0 {
            return 1.0;
        }
        self.refreshes as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn eye_scaled(n: usize, s: f32) -> Mat {
        Mat::eye(n).scale(s)
    }

    #[test]
    fn stable_statistics_grow_fibonacci() {
        let mut st = StaleState::new(0.1);
        let x = eye_scaled(4, 1.0);
        let mut t = 1;
        let mut deltas = Vec::new();
        for _ in 0..8 {
            assert!(st.due(t));
            let d = st.refresh(t, &x);
            deltas.push(d);
            t += d;
        }
        // first two refreshes build history; afterwards Δ grows like
        // Fibonacci sums: 1,1,2,3,5,8,...
        assert_eq!(&deltas[..7], &[1, 1, 2, 3, 5, 8, 13]);
    }

    #[test]
    fn drifting_statistics_halve_interval() {
        let mut st = StaleState::new(0.1);
        let mut t = 1;
        // stable phase grows the interval
        for i in 0..6 {
            let d = st.refresh(t, &eye_scaled(4, 1.0));
            t += d;
            let _ = i;
        }
        let grown = st.delta;
        assert!(grown >= 8);
        // now a large drift: interval halves
        let d = st.refresh(t, &eye_scaled(4, 10.0));
        assert_eq!(d, (grown / 2).max(1));
    }

    #[test]
    fn drift_vs_before_last_holds_interval() {
        let mut st = StaleState::new(0.1);
        // refresh 1: X (no history) -> Δ=1
        st.refresh(1, &eye_scaled(4, 1.0));
        // refresh 2: similar to last (only one history entry) -> Δ=1
        st.refresh(2, &eye_scaled(4, 1.0));
        // refresh 3: similar to both -> grow (1+1=2)
        assert_eq!(st.refresh(3, &eye_scaled(4, 1.0)), 2);
        // refresh 4: similar to X₋₁ (1.0? no: last is 1.0) — craft a value
        // similar to last but NOT to before-last: last=1.0, before=1.0, so
        // use drift within α of last but outside α of before-last —
        // impossible when they're equal; instead step the value slowly:
        // 1.0 -> 1.05 (similar, α=0.1) with before-last 1.0: |1.05-1|/1 =
        // .05 similar too. Use 3% steps accumulating: last=1.05.
        assert_eq!(st.refresh(5, &eye_scaled(4, 1.05)), 3); // grows again
        // now 1.13: vs last (1.05): 7.6% similar; vs before-last (1.0):
        // 13% NOT similar -> hold Δ
        let before = st.delta;
        let d = st.refresh(8, &eye_scaled(4, 1.13));
        assert_eq!(d, before);
    }

    #[test]
    fn similarity_threshold_edges() {
        let st = StaleState::new(0.1);
        let b = eye_scaled(3, 1.0);
        assert!(st.similar(&eye_scaled(3, 1.05), &b));
        assert!(!st.similar(&eye_scaled(3, 1.2), &b));
        // zero reference: only zero is similar
        let z = Mat::zeros(3, 3);
        assert!(st.similar(&Mat::zeros(3, 3), &z));
        assert!(!st.similar(&b, &z));
    }

    #[test]
    fn due_respects_schedule() {
        let mut st = StaleState::new(0.1);
        assert!(st.due(1));
        st.refresh(1, &eye_scaled(2, 1.0));
        st.refresh(2, &eye_scaled(2, 1.0));
        let d = st.refresh(3, &eye_scaled(2, 1.0));
        assert_eq!(d, 2);
        assert!(!st.due(4));
        assert!(st.due(5));
    }

    #[test]
    fn prop_interval_always_positive_and_bounded() {
        // property: any drift sequence keeps Δ ≥ 1 and the interval
        // never more than doubles the Fibonacci growth bound
        prop::check(
            21,
            50,
            40,
            |rng: &mut Rng, size| {
                (0..size).map(|_| 0.5 + rng.f32() * 2.0).collect::<Vec<f32>>()
            },
            |scales| {
                let mut st = StaleState::new(0.1);
                let mut t = 1;
                let mut prev_delta = 1;
                for &s in scales {
                    let d = st.refresh(t, &eye_scaled(3, s));
                    if d == 0 {
                        return false;
                    }
                    // growth at most Δ+Δ₋₁
                    if d > prev_delta * 2 + 1 {
                        return false;
                    }
                    prev_delta = d.max(prev_delta);
                    t += d;
                }
                true
            },
        );
    }

    #[test]
    fn refresh_fraction_reporting() {
        let mut st = StaleState::new(0.1);
        st.refresh(1, &eye_scaled(2, 1.0));
        for _ in 0..9 {
            st.note_skip();
        }
        assert!((st.refresh_fraction() - 0.1).abs() < 1e-9);
    }
}
