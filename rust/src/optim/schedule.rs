//! Learning-rate & momentum schedules (§6.2).
//!
//! Polynomial decay (Eq. 21):
//!   η(e) = η₀ · (1 − (e − e_start)/(e_end − e_start))^p_decay
//! Momentum coupled to the LR (Eq. 22): m(e) = (m₀/η₀) · η(e), keeping
//! m/η constant so late-training updates don't get swamped by stale
//! momentum when η decays rapidly.

/// Per-batch-size hyperparameters (Table 2 of the paper).
#[derive(Clone, Debug)]
pub struct HyperParams {
    pub alpha_mixup: f64,
    pub p_decay: f64,
    pub e_start: f64,
    pub e_end: f64,
    pub eta0: f64,
    pub m0: f64,
    pub lambda: f32,
}

impl HyperParams {
    /// The paper's Table 2 row for a given (real) batch size; used by the
    /// Table-2 bench to mirror the published configuration space. Scaled
    /// runs pick the nearest row.
    pub fn table2(bs: usize) -> HyperParams {
        // (alpha_mixup, p_decay, e_start, e_end, eta0, m0, lambda)
        let rows: [(usize, f64, f64, f64, f64, f64, f64, f32); 6] = [
            (4_096, 0.4, 11.0, 1.0, 53.0, 8.18e-3, 0.997, 2.5e-4),
            (8_192, 0.4, 8.0, 1.0, 53.5, 1.25e-2, 0.993, 2.5e-4),
            (16_384, 0.4, 8.0, 1.0, 53.5, 2.5e-2, 0.985, 2.5e-4),
            (32_768, 0.6, 3.5, 1.5, 49.5, 3.0e-2, 0.97, 2.0e-4),
            (65_536, 0.6, 2.9, 2.0, 64.5, 4.0e-2, 0.95, 1.5e-4),
            (131_072, 1.0, 2.9, 3.0, 100.0, 7.0e-2, 0.93, 1.0e-4),
        ];
        let row = rows
            .iter()
            .min_by_key(|r| (r.0 as i64 - bs as i64).abs())
            .unwrap();
        HyperParams {
            alpha_mixup: row.1,
            p_decay: row.2,
            e_start: row.3,
            e_end: row.4,
            eta0: row.5,
            m0: row.6,
            lambda: row.7,
        }
    }
}

/// Per-step learning-rate/momentum policy — the schedule half of the
/// composable optimizer API. [`Schedule`] (polynomial decay + coupled
/// momentum, Eqs. 21-22) is the stock implementation; custom policies
/// plug into `TrainerBuilder::schedule`.
pub trait SchedulePolicy: Send + Sync {
    /// η at a step.
    fn lr(&self, step: u64) -> f64;
    /// m at a step.
    fn momentum(&self, step: u64) -> f64;
    /// Fractional epoch of a step (for logging and epoch-based decay).
    fn epoch_of(&self, step: u64) -> f64;
}

impl SchedulePolicy for Schedule {
    fn lr(&self, step: u64) -> f64 {
        Schedule::lr(self, step)
    }

    fn momentum(&self, step: u64) -> f64 {
        Schedule::momentum(self, step)
    }

    fn epoch_of(&self, step: u64) -> f64 {
        Schedule::epoch_of(self, step)
    }
}

/// Stateful schedule evaluated per step.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub hp: HyperParams,
    pub steps_per_epoch: f64,
    /// linear warmup epochs before the decay starts (0 = none)
    pub warmup_epochs: f64,
}

impl Schedule {
    pub fn new(hp: HyperParams, steps_per_epoch: usize) -> Self {
        Schedule { hp, steps_per_epoch: steps_per_epoch.max(1) as f64, warmup_epochs: 0.0 }
    }

    pub fn epoch_of(&self, step: u64) -> f64 {
        step as f64 / self.steps_per_epoch
    }

    /// η at a step (Eq. 21 + optional warmup).
    pub fn lr(&self, step: u64) -> f64 {
        let e = self.epoch_of(step);
        if self.warmup_epochs > 0.0 && e < self.warmup_epochs {
            return self.hp.eta0 * (e / self.warmup_epochs).max(1e-3);
        }
        let hp = &self.hp;
        if e <= hp.e_start {
            return hp.eta0;
        }
        if e >= hp.e_end {
            return 0.0;
        }
        let frac = (e - hp.e_start) / (hp.e_end - hp.e_start);
        hp.eta0 * (1.0 - frac).powf(hp.p_decay)
    }

    /// m at a step (Eq. 22): fixed m/η ratio.
    pub fn momentum(&self, step: u64) -> f64 {
        self.hp.m0 / self.hp.eta0 * self.lr(step)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Schedule {
        Schedule::new(HyperParams::table2(32_768), 39)
    }

    #[test]
    fn table2_lookup_exact_and_nearest() {
        assert_eq!(HyperParams::table2(32_768).eta0, 3.0e-2);
        assert_eq!(HyperParams::table2(30_000).eta0, 3.0e-2);
        assert_eq!(HyperParams::table2(1_000).eta0, 8.18e-3);
        assert_eq!(HyperParams::table2(131_072).m0, 0.93);
    }

    #[test]
    fn lr_flat_then_decays_to_zero() {
        let s = sched();
        // before e_start (1.5 epochs = ~58 steps): flat
        assert_eq!(s.lr(0), 0.03);
        assert_eq!(s.lr(39), 0.03); // epoch 1 < 1.5
        // decaying region: monotone non-increasing
        let mut prev = f64::INFINITY;
        for step in (60..2000).step_by(39) {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-12);
            prev = lr;
        }
        // past e_end (49.5 epochs = ~1930 steps): zero
        assert_eq!(s.lr(2000), 0.0);
    }

    #[test]
    fn momentum_tracks_lr_ratio() {
        let s = sched();
        for step in [0u64, 100, 500, 1500] {
            let lr = s.lr(step);
            let m = s.momentum(step);
            if lr > 0.0 {
                assert!((m / lr - s.hp.m0 / s.hp.eta0).abs() < 1e-9);
            } else {
                assert_eq!(m, 0.0);
            }
        }
    }

    #[test]
    fn warmup_ramps_linearly() {
        let mut s = sched();
        s.warmup_epochs = 1.0;
        assert!(s.lr(1) < s.lr(20));
        assert!(s.lr(20) < s.lr(39));
        assert!((s.lr(39) - s.hp.eta0).abs() < 1e-9);
    }

    #[test]
    fn decay_exponent_shapes_curve() {
        // higher p_decay decays faster early
        let hp_fast = HyperParams { p_decay: 11.0, ..HyperParams::table2(32_768) };
        let hp_slow = HyperParams { p_decay: 2.0, ..HyperParams::table2(32_768) };
        let f = Schedule::new(hp_fast, 39);
        let s = Schedule::new(hp_slow, 39);
        let mid = 800;
        assert!(f.lr(mid) < s.lr(mid));
    }
}
