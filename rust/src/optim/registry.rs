//! Optimizer registry: the single place that maps `--optim` names to
//! [`Preconditioner`] implementations. Adding an optimizer = implement
//! the trait + add one row here; the CLI, harness (`SPNGD_OPTIM`), CI
//! matrix and benches all resolve through this lookup, and unknown names
//! are a hard error listing the valid choices.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::optim::first_order::{Lars, Sgd};
use crate::optim::precond::Preconditioner;
use crate::optim::spngd::SpNgd;

/// Registered optimizer names, in presentation order.
pub const OPTIMIZER_NAMES: &[&str] = &["spngd", "sgd", "lars"];

/// Default-configured optimizer by registry name. Unknown names are a
/// hard error listing the valid choices.
pub fn by_name(name: &str) -> Result<Arc<dyn Preconditioner>> {
    match name {
        "spngd" => Ok(Arc::new(SpNgd::default())),
        "sgd" => Ok(Arc::new(Sgd)),
        "lars" => Ok(Arc::new(Lars::default())),
        other => bail!(
            "unknown optimizer '{other}' (valid choices: {})",
            OPTIMIZER_NAMES.join(" | ")
        ),
    }
}

/// Default SP-NGD (emp Fisher, unitBN, no stale scheduler).
pub fn spngd() -> Arc<dyn Preconditioner> {
    Arc::new(SpNgd::default())
}

/// The SGD-with-momentum baseline.
pub fn sgd() -> Arc<dyn Preconditioner> {
    Arc::new(Sgd)
}

/// Default LARS.
pub fn lars() -> Arc<dyn Preconditioner> {
    Arc::new(Lars::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_name_resolves() {
        for name in OPTIMIZER_NAMES {
            let opt = by_name(name).unwrap();
            assert_eq!(&opt.name(), name);
        }
    }

    #[test]
    fn unknown_name_is_hard_error_listing_choices() {
        let err = by_name("adam").unwrap_err().to_string();
        assert!(err.contains("unknown optimizer 'adam'"), "{err}");
        for name in OPTIMIZER_NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
    }
}
