//! Parameter update rules: SP-NGD momentum update (Eq. 23), Normalizing
//! Weights rescaling (Eq. 24), the SGD baseline, and the [`UpdateRule`]
//! stage that applies a preconditioned direction to a weight (trust-ratio
//! clip → momentum step → optional Normalizing-Weights rescale).

use crate::runtime::HostTensor;

/// What the update rule knows about the parameter being updated.
pub struct ParamCtx<'a> {
    /// owning layer kind: "conv" | "fc" | "bn"
    pub layer_kind: &'a str,
    /// layer output dimension (Normalizing Weights target norm √(2·d_out))
    pub d_out: usize,
}

/// Stage 4b's final step: apply a direction to one parameter. Shared by
/// every [`Preconditioner`](super::Preconditioner) so optimizers compose
/// with clipping/momentum/rescale policies instead of reimplementing
/// them.
pub trait UpdateRule: Send + Sync {
    fn apply(
        &self,
        w: &mut HostTensor,
        v: &mut HostTensor,
        dir: &mut HostTensor,
        lr: f32,
        momentum: f32,
        ctx: &ParamCtx,
    );
}

/// The default rule — what the pre-refactor trainer hardcoded:
/// trust-ratio clip of the preconditioned direction, the Eq. 23 momentum
/// update, and (optionally) Normalizing Weights for conv layers.
#[derive(Clone, Copy, Debug)]
pub struct MomentumRule {
    /// per-layer update-norm clip: ||lr·dir|| ≤ clip·||w|| (0 = off).
    /// Stabilizes the preconditioner when the Fisher collapses near zero
    /// training loss (a regime ImageNet-scale runs never reach).
    pub clip_update_ratio: f32,
    /// Normalizing-Weights rescale (Eq. 24) for conv layers
    pub weight_rescale: bool,
}

impl Default for MomentumRule {
    fn default() -> Self {
        MomentumRule { clip_update_ratio: 0.3, weight_rescale: false }
    }
}

impl UpdateRule for MomentumRule {
    fn apply(
        &self,
        w: &mut HostTensor,
        v: &mut HostTensor,
        dir: &mut HostTensor,
        lr: f32,
        momentum: f32,
        ctx: &ParamCtx,
    ) {
        clip_direction(self.clip_update_ratio, dir, w, lr);
        spngd_update(w, v, dir, lr, momentum);
        // Normalizing Weights (Eq. 24) — conv layers (BN-covered);
        // the FC head keeps its scale (no BN follows it here).
        if self.weight_rescale && ctx.layer_kind == "conv" {
            rescale_weight(w, ctx.d_out);
        }
    }
}

/// Trust-ratio clip (applied to the *preconditioned* direction):
/// ensures ||lr * dir|| <= clip * ||w||.
pub fn clip_direction(clip: f32, dir: &mut HostTensor, w: &HostTensor, lr: f32) {
    if clip <= 0.0 || lr <= 0.0 {
        return;
    }
    let wn = w.norm().max(1e-3);
    let dn = dir.norm() * lr;
    if dn > clip * wn {
        dir.scale_inplace(clip * wn / dn);
    }
}

/// Momentum state: v(t) = w(t) − w(t−1) per parameter (Eq. 23 defines the
/// momentum term from the previous update).
#[derive(Clone, Debug, Default)]
pub struct Velocity {
    pub v: Vec<HostTensor>,
}

impl Velocity {
    pub fn zeros_like(params: &[HostTensor]) -> Self {
        Velocity { v: params.iter().map(|p| HostTensor::zeros(p.shape.clone())).collect() }
    }
}

/// SP-NGD update (Eq. 23): w ← w − η·(F̂+λI)⁻¹∇L + m·v, where `direction`
/// is the preconditioned gradient from Stage 4. Updates velocity in place.
pub fn spngd_update(
    w: &mut HostTensor,
    v: &mut HostTensor,
    direction: &HostTensor,
    lr: f32,
    momentum: f32,
) {
    assert_eq!(w.shape, direction.shape);
    assert_eq!(w.shape, v.shape);
    for i in 0..w.data.len() {
        let dw = -lr * direction.data[i] + momentum * v.data[i];
        w.data[i] += dw;
        v.data[i] = dw;
    }
}

/// SGD with momentum baseline: same signature, direction = raw gradient.
pub fn sgd_update(
    w: &mut HostTensor,
    v: &mut HostTensor,
    grad: &HostTensor,
    lr: f32,
    momentum: f32,
) {
    spngd_update(w, v, grad, lr, momentum);
}

/// Normalizing Weights (Eq. 24): rescale conv/fc weights to norm
/// √(2·d_out) after the update (ε stabilizes the division).
pub fn rescale_weight(w: &mut HostTensor, d_out: usize) {
    const EPS: f32 = 1e-9;
    let target = (2.0 * d_out as f32).sqrt();
    let norm = w.norm();
    let s = target / (norm + EPS);
    w.scale_inplace(s);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_rule_matches_clip_then_update_then_rescale() {
        // the rule must reproduce the exact pre-refactor op sequence
        let mut w1 = HostTensor::new(vec![2, 2], vec![1.0, 2.0, -1.0, 0.5]);
        let mut v1 = HostTensor::zeros(vec![2, 2]);
        let mut d1 = HostTensor::new(vec![2, 2], vec![10.0, -10.0, 5.0, 5.0]);
        let (lr, mom) = (0.1f32, 0.9f32);
        let rule = MomentumRule { clip_update_ratio: 0.3, weight_rescale: true };
        let ctx = ParamCtx { layer_kind: "conv", d_out: 2 };
        rule.apply(&mut w1, &mut v1, &mut d1, lr, mom, &ctx);

        let mut w2 = HostTensor::new(vec![2, 2], vec![1.0, 2.0, -1.0, 0.5]);
        let mut v2 = HostTensor::zeros(vec![2, 2]);
        let mut d2 = HostTensor::new(vec![2, 2], vec![10.0, -10.0, 5.0, 5.0]);
        clip_direction(0.3, &mut d2, &w2, lr);
        spngd_update(&mut w2, &mut v2, &d2, lr, mom);
        rescale_weight(&mut w2, 2);
        assert_eq!(w1.data, w2.data);
        assert_eq!(v1.data, v2.data);
    }

    #[test]
    fn momentum_rule_skips_rescale_for_non_conv() {
        let mut w = HostTensor::new(vec![2], vec![1.0, 1.0]);
        let mut v = HostTensor::zeros(vec![2]);
        let mut d = HostTensor::new(vec![2], vec![0.5, -0.5]);
        let rule = MomentumRule { clip_update_ratio: 0.0, weight_rescale: true };
        rule.apply(&mut w, &mut v, &mut d, 0.1, 0.0, &ParamCtx { layer_kind: "fc", d_out: 2 });
        assert_eq!(w.data, vec![0.95, 1.05]); // no rescale applied
    }

    #[test]
    fn clip_caps_update_norm() {
        let w = HostTensor::new(vec![2], vec![3.0, 4.0]); // ||w|| = 5
        let mut d = HostTensor::new(vec![2], vec![30.0, 40.0]); // ||d|| = 50
        clip_direction(0.3, &mut d, &w, 1.0);
        assert!((d.norm() - 1.5).abs() < 1e-5); // 0.3 * 5
        // under the cap: untouched
        let mut d2 = HostTensor::new(vec![2], vec![0.1, 0.0]);
        clip_direction(0.3, &mut d2, &w, 1.0);
        assert_eq!(d2.data, vec![0.1, 0.0]);
    }

    fn t(data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::new(vec![n], data)
    }

    #[test]
    fn update_applies_lr_and_momentum() {
        let mut w = t(vec![1.0, 1.0]);
        let mut v = t(vec![0.0, 0.0]);
        let d = t(vec![0.5, -0.5]);
        spngd_update(&mut w, &mut v, &d, 0.1, 0.9);
        assert_eq!(w.data, vec![0.95, 1.05]);
        assert_eq!(v.data, vec![-0.05, 0.05]);
        // second step: momentum carries
        spngd_update(&mut w, &mut v, &d, 0.1, 0.9);
        assert!((w.data[0] - (0.95 - 0.05 - 0.045)).abs() < 1e-6);
    }

    #[test]
    fn velocity_equals_weight_delta() {
        // Eq. 23: v(t) = w(t) − w(t−1)
        let mut w = t(vec![2.0, -1.0, 0.5]);
        let w_prev = w.clone();
        let mut v = t(vec![0.1, 0.2, -0.1]);
        let d = t(vec![1.0, 0.0, 2.0]);
        spngd_update(&mut w, &mut v, &d, 0.05, 0.5);
        for i in 0..3 {
            assert!((v.data[i] - (w.data[i] - w_prev.data[i])).abs() < 1e-7);
        }
    }

    #[test]
    fn rescale_hits_target_norm() {
        let mut w = HostTensor::new(vec![4, 2], vec![3.0; 8]);
        rescale_weight(&mut w, 4);
        assert!((w.norm() - (8.0f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn rescale_zero_weight_stable() {
        let mut w = HostTensor::zeros(vec![4]);
        rescale_weight(&mut w, 2);
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sgd_is_ngd_with_identity_preconditioner() {
        let mut w1 = t(vec![1.0]);
        let mut v1 = t(vec![0.0]);
        let mut w2 = w1.clone();
        let mut v2 = v1.clone();
        let g = t(vec![0.3]);
        sgd_update(&mut w1, &mut v1, &g, 0.1, 0.9);
        spngd_update(&mut w2, &mut v2, &g, 0.1, 0.9);
        assert_eq!(w1.data, w2.data);
    }
}
