//! Parameter update rules: SP-NGD momentum update (Eq. 23), Normalizing
//! Weights rescaling (Eq. 24), and the SGD baseline.

use crate::runtime::HostTensor;

/// Momentum state: v(t) = w(t) − w(t−1) per parameter (Eq. 23 defines the
/// momentum term from the previous update).
#[derive(Clone, Debug, Default)]
pub struct Velocity {
    pub v: Vec<HostTensor>,
}

impl Velocity {
    pub fn zeros_like(params: &[HostTensor]) -> Self {
        Velocity { v: params.iter().map(|p| HostTensor::zeros(p.shape.clone())).collect() }
    }
}

/// SP-NGD update (Eq. 23): w ← w − η·(F̂+λI)⁻¹∇L + m·v, where `direction`
/// is the preconditioned gradient from Stage 4. Updates velocity in place.
pub fn spngd_update(
    w: &mut HostTensor,
    v: &mut HostTensor,
    direction: &HostTensor,
    lr: f32,
    momentum: f32,
) {
    assert_eq!(w.shape, direction.shape);
    assert_eq!(w.shape, v.shape);
    for i in 0..w.data.len() {
        let dw = -lr * direction.data[i] + momentum * v.data[i];
        w.data[i] += dw;
        v.data[i] = dw;
    }
}

/// SGD with momentum baseline: same signature, direction = raw gradient.
pub fn sgd_update(
    w: &mut HostTensor,
    v: &mut HostTensor,
    grad: &HostTensor,
    lr: f32,
    momentum: f32,
) {
    spngd_update(w, v, grad, lr, momentum);
}

/// Normalizing Weights (Eq. 24): rescale conv/fc weights to norm
/// √(2·d_out) after the update (ε stabilizes the division).
pub fn rescale_weight(w: &mut HostTensor, d_out: usize) {
    const EPS: f32 = 1e-9;
    let target = (2.0 * d_out as f32).sqrt();
    let norm = w.norm();
    let s = target / (norm + EPS);
    w.scale_inplace(s);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(data: Vec<f32>) -> HostTensor {
        let n = data.len();
        HostTensor::new(vec![n], data)
    }

    #[test]
    fn update_applies_lr_and_momentum() {
        let mut w = t(vec![1.0, 1.0]);
        let mut v = t(vec![0.0, 0.0]);
        let d = t(vec![0.5, -0.5]);
        spngd_update(&mut w, &mut v, &d, 0.1, 0.9);
        assert_eq!(w.data, vec![0.95, 1.05]);
        assert_eq!(v.data, vec![-0.05, 0.05]);
        // second step: momentum carries
        spngd_update(&mut w, &mut v, &d, 0.1, 0.9);
        assert!((w.data[0] - (0.95 - 0.05 - 0.045)).abs() < 1e-6);
    }

    #[test]
    fn velocity_equals_weight_delta() {
        // Eq. 23: v(t) = w(t) − w(t−1)
        let mut w = t(vec![2.0, -1.0, 0.5]);
        let w_prev = w.clone();
        let mut v = t(vec![0.1, 0.2, -0.1]);
        let d = t(vec![1.0, 0.0, 2.0]);
        spngd_update(&mut w, &mut v, &d, 0.05, 0.5);
        for i in 0..3 {
            assert!((v.data[i] - (w.data[i] - w_prev.data[i])).abs() < 1e-7);
        }
    }

    #[test]
    fn rescale_hits_target_norm() {
        let mut w = HostTensor::new(vec![4, 2], vec![3.0; 8]);
        rescale_weight(&mut w, 4);
        assert!((w.norm() - (8.0f32).sqrt()).abs() < 1e-4);
    }

    #[test]
    fn rescale_zero_weight_stable() {
        let mut w = HostTensor::zeros(vec![4]);
        rescale_weight(&mut w, 2);
        assert!(w.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sgd_is_ngd_with_identity_preconditioner() {
        let mut w1 = t(vec![1.0]);
        let mut v1 = t(vec![0.0]);
        let mut w2 = w1.clone();
        let mut v2 = v1.clone();
        let g = t(vec![0.3]);
        sgd_update(&mut w1, &mut v1, &g, 0.1, 0.9);
        spngd_update(&mut w2, &mut v2, &g, 0.1, 0.9);
        assert_eq!(w1.data, w2.data);
    }
}
