//! The dynamic micro-batching queue: concurrent `/v1/predict` handlers
//! enqueue 1..=`max_batch` rows each and block on a ticket; one batcher
//! thread coalesces whatever is queued into a single forward pass,
//! flushing when `max_batch` rows are ready **or** the oldest row has
//! waited `max_wait_us` — whichever comes first. Latency under light
//! load is bounded by the deadline; throughput under heavy load rides
//! the model's full static batch.
//!
//! Queue-wait and batch-assembly are wrapped in `util::obs` spans so a
//! trace of a serving process shows where request time goes, exactly as
//! training traces do for step time.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::obs::{self, Cat};

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct QueueCfg {
    /// rows per forward — the model's static batch (or less)
    pub max_batch: usize,
    /// how long the first-arrived row waits for co-riders (µs)
    pub max_wait_us: u64,
}

/// Batcher counters, all monotonic. Exposed verbatim by `/v1/stats`.
#[derive(Default)]
pub struct QueueStats {
    /// forward passes run
    pub batches: AtomicU64,
    /// rows predicted (sum of live rows over batches)
    pub rows: AtomicU64,
    /// flushes triggered by a full batch
    pub full_flushes: AtomicU64,
    /// flushes triggered by the deadline
    pub timeout_flushes: AtomicU64,
    /// cumulative queue wait of flushed batches (µs, oldest row)
    pub queue_wait_us: AtomicU64,
    /// cumulative forward time (µs)
    pub forward_us: AtomicU64,
}

/// Per-batch result slot: the handler blocks on it, the batcher fills
/// it once (logits per row, or one error shared by the batch).
struct SlotInner {
    m: Mutex<Option<Result<Vec<Vec<f32>>, String>>>,
    cv: Condvar,
}

struct Item {
    rows: Vec<Vec<f32>>,
    enq: Instant,
    slot: Arc<SlotInner>,
}

/// A claim on one enqueued request's results.
pub struct Ticket {
    slot: Arc<SlotInner>,
}

impl Ticket {
    /// Block until the batcher fills the slot.
    pub fn wait(self) -> Result<Vec<Vec<f32>>, String> {
        let mut g = self.slot.m.lock().unwrap();
        while g.is_none() {
            g = self.slot.cv.wait(g).unwrap();
        }
        g.take().expect("slot filled")
    }
}

struct QState {
    items: VecDeque<Item>,
    rows_queued: usize,
    shutdown: bool,
}

pub struct BatchQueue {
    cfg: QueueCfg,
    st: Mutex<QState>,
    cv: Condvar,
    pub stats: QueueStats,
}

impl BatchQueue {
    pub fn new(cfg: QueueCfg) -> Arc<BatchQueue> {
        assert!(cfg.max_batch >= 1, "max_batch must be >= 1");
        Arc::new(BatchQueue {
            cfg,
            st: Mutex::new(QState {
                items: VecDeque::new(),
                rows_queued: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
            stats: QueueStats::default(),
        })
    }

    pub fn cfg(&self) -> QueueCfg {
        self.cfg
    }

    /// Enqueue one request (1..=`max_batch` rows) and get a ticket. A
    /// request larger than the batch cap is the caller's to split — one
    /// flush must always be able to carry a whole request.
    pub fn enqueue(&self, rows: Vec<Vec<f32>>) -> Result<Ticket, String> {
        if rows.is_empty() {
            return Err("empty predict request".to_string());
        }
        if rows.len() > self.cfg.max_batch {
            return Err(format!(
                "request has {} rows, the batch cap is {} — split the request",
                rows.len(),
                self.cfg.max_batch
            ));
        }
        let slot = Arc::new(SlotInner { m: Mutex::new(None), cv: Condvar::new() });
        let mut st = self.st.lock().unwrap();
        if st.shutdown {
            return Err("server is shutting down".to_string());
        }
        st.rows_queued += rows.len();
        st.items.push_back(Item { rows, enq: Instant::now(), slot: slot.clone() });
        drop(st);
        self.cv.notify_all();
        Ok(Ticket { slot })
    }

    /// Stop accepting work. The batcher drains what is queued (each
    /// remaining ticket still gets an answer) and then exits.
    pub fn shutdown(&self) {
        self.st.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// The batcher loop — run from one dedicated thread. `forward` maps
    /// assembled rows to per-row logits; its error (if any) fans out to
    /// every ticket of the batch.
    pub fn run<F>(&self, mut forward: F)
    where
        F: FnMut(&[Vec<f32>]) -> Result<Vec<Vec<f32>>, String>,
    {
        loop {
            let mut st = self.st.lock().unwrap();
            while st.items.is_empty() {
                if st.shutdown {
                    return;
                }
                st = self.cv.wait(st).unwrap();
            }

            // the oldest row opens the coalescing window
            let opened = st.items.front().expect("non-empty").enq;
            let deadline = opened + Duration::from_micros(self.cfg.max_wait_us);
            {
                let _wait = obs::span("serve_queue_wait", Cat::Data);
                loop {
                    if st.rows_queued >= self.cfg.max_batch || st.shutdown {
                        break;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (g, _timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                    st = g;
                }
            }

            // drain whole requests up to the row cap (each fits alone by
            // the enqueue invariant)
            let asm = obs::span("serve_batch_assemble", Cat::Data);
            let mut flushed: Vec<Item> = Vec::new();
            let mut nrows = 0usize;
            while let Some(head) = st.items.front() {
                if nrows + head.rows.len() > self.cfg.max_batch {
                    break;
                }
                nrows += head.rows.len();
                flushed.push(st.items.pop_front().expect("front exists"));
            }
            st.rows_queued -= nrows;
            let full = nrows >= self.cfg.max_batch;
            drop(st);

            let flat: Vec<Vec<f32>> =
                flushed.iter().flat_map(|it| it.rows.iter().cloned()).collect();
            drop(asm);

            let waited = opened.elapsed();
            if full {
                self.stats.full_flushes.fetch_add(1, Ordering::Relaxed);
            } else {
                self.stats.timeout_flushes.fetch_add(1, Ordering::Relaxed);
            }
            self.stats
                .queue_wait_us
                .fetch_add(waited.as_micros() as u64, Ordering::Relaxed);

            let t0 = Instant::now();
            let result = forward(&flat);
            self.stats
                .forward_us
                .fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
            self.stats.batches.fetch_add(1, Ordering::Relaxed);
            self.stats.rows.fetch_add(nrows as u64, Ordering::Relaxed);

            match result {
                Ok(logits) => {
                    debug_assert_eq!(logits.len(), nrows);
                    let mut off = 0usize;
                    for it in flushed {
                        let n = it.rows.len();
                        let part: Vec<Vec<f32>> = logits
                            .get(off..off + n)
                            .map(|s| s.to_vec())
                            .unwrap_or_default();
                        off += n;
                        if part.len() == n {
                            fill(&it.slot, Ok(part));
                        } else {
                            fill(
                                &it.slot,
                                Err("forward returned fewer rows than requested".to_string()),
                            );
                        }
                    }
                }
                Err(e) => {
                    for it in flushed {
                        fill(&it.slot, Err(e.clone()));
                    }
                }
            }
        }
    }
}

fn fill(slot: &SlotInner, r: Result<Vec<Vec<f32>>, String>) {
    *slot.m.lock().unwrap() = Some(r);
    slot.cv.notify_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    /// forward = identity-ish: logits row i = [sum(row), row len]
    fn echo_forward(rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        Ok(rows.iter().map(|r| vec![r.iter().sum::<f32>(), r.len() as f32]).collect())
    }

    fn spawn_batcher(q: &Arc<BatchQueue>) -> std::thread::JoinHandle<()> {
        let qc = q.clone();
        std::thread::Builder::new()
            .name("test-batcher".into())
            .spawn(move || qc.run(echo_forward))
            .unwrap()
    }

    #[test]
    fn two_concurrent_requests_coalesce_into_one_batch() {
        // max_wait far above scheduling noise: the flush we observe can
        // only be the *full* flush of both requests riding together
        let q = BatchQueue::new(QueueCfg { max_batch: 2, max_wait_us: 5_000_000 });
        let batcher = spawn_batcher(&q);
        let (qa, qb) = (q.clone(), q.clone());
        let a = std::thread::spawn(move || qa.enqueue(vec![vec![1.0, 2.0]]).unwrap().wait());
        let b = std::thread::spawn(move || qb.enqueue(vec![vec![10.0]]).unwrap().wait());
        let ra = a.join().unwrap().unwrap();
        let rb = b.join().unwrap().unwrap();
        assert_eq!(ra, vec![vec![3.0, 2.0]]);
        assert_eq!(rb, vec![vec![10.0, 1.0]]);
        assert_eq!(q.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(q.stats.rows.load(Ordering::Relaxed), 2);
        assert_eq!(q.stats.full_flushes.load(Ordering::Relaxed), 1);
        q.shutdown();
        batcher.join().unwrap();
    }

    #[test]
    fn deadline_flushes_a_lonely_request() {
        let q = BatchQueue::new(QueueCfg { max_batch: 64, max_wait_us: 2_000 });
        let batcher = spawn_batcher(&q);
        let r = q.enqueue(vec![vec![4.0, 4.0]]).unwrap().wait().unwrap();
        assert_eq!(r, vec![vec![8.0, 2.0]]);
        assert_eq!(q.stats.timeout_flushes.load(Ordering::Relaxed), 1);
        q.shutdown();
        batcher.join().unwrap();
    }

    #[test]
    fn oversized_and_empty_requests_are_rejected_at_enqueue() {
        let q = BatchQueue::new(QueueCfg { max_batch: 2, max_wait_us: 1 });
        assert!(q.enqueue(vec![]).is_err());
        assert!(q.enqueue(vec![vec![0.0]; 3]).is_err());
    }

    #[test]
    fn shutdown_drains_queued_work_then_exits() {
        let q = BatchQueue::new(QueueCfg { max_batch: 8, max_wait_us: 60_000_000 });
        let t = q.enqueue(vec![vec![5.0]]).unwrap();
        // shutdown before the batcher ever runs: the pending ticket must
        // still be answered (drain), then the loop exits
        q.shutdown();
        let batcher = spawn_batcher(&q);
        assert_eq!(t.wait().unwrap(), vec![vec![5.0, 1.0]]);
        batcher.join().unwrap();
        assert!(q.enqueue(vec![vec![1.0]]).is_err(), "post-shutdown enqueue must fail");
    }

    #[test]
    fn forward_error_fans_out_to_every_ticket_of_the_batch() {
        let q = BatchQueue::new(QueueCfg { max_batch: 2, max_wait_us: 5_000_000 });
        let qc = q.clone();
        let batcher = std::thread::spawn(move || {
            qc.run(|_rows| Err("engine on fire".to_string()))
        });
        let (qa, qb) = (q.clone(), q.clone());
        let a = std::thread::spawn(move || qa.enqueue(vec![vec![1.0]]).unwrap().wait());
        let b = std::thread::spawn(move || qb.enqueue(vec![vec![2.0]]).unwrap().wait());
        assert!(a.join().unwrap().unwrap_err().contains("engine on fire"));
        assert!(b.join().unwrap().unwrap_err().contains("engine on fire"));
        q.shutdown();
        batcher.join().unwrap();
    }
}
