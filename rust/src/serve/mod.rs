//! `spngd serve` — the inference side of the train→inference loop.
//!
//! A training run leaves an SPCK checkpoint (see [`crate::ckpt`]);
//! this module loads its weights + BN running statistics into the same
//! [`crate::runtime::Executor`] training used and serves typed HTTP
//! routes over a dependency-free `std::net` server:
//!
//! - `GET /healthz` — liveness + model identity;
//! - `POST /v1/predict` — `{"x": [[f32; C·H·W], ...]}` → logits +
//!   argmax, answered through the dynamic micro-batching [`queue`];
//! - `GET /v1/stats` — request/batch/latency counters.
//!
//! Requests ride a `util::pool::Pool` of connection handlers; each
//! predict enqueues into the [`queue::BatchQueue`] and blocks on a
//! ticket while the single batcher thread coalesces concurrent requests
//! into full-batch forward passes (`predict_*` executables — the
//! inference-only contract in `runtime::native::net::run_predict`).

pub mod http;
pub mod queue;

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::ckpt::{self, ByteReader, Checkpoint, SEC_BN, SEC_PARAM};
use crate::runtime::{Executor, HostTensor, Manifest, ModelManifest};
use crate::util::json::{obj, Json};
use crate::util::obs::{self, Cat};
use crate::util::pool::Pool;
use crate::{debug, info, warn_};

/// Inference-only view of a trained model: weights + BN running stats
/// behind the runtime's predict executable. Thread-safe (`&self`
/// forward), so the batcher and tests can share it.
pub struct Predictor {
    engine: Arc<dyn Executor>,
    model: ModelManifest,
    params: Vec<HostTensor>,
    bn: Vec<(HostTensor, HostTensor)>,
    /// training step the weights were saved at (checkpoint META)
    step: u64,
}

impl Predictor {
    /// Load weights from a parsed checkpoint. Validates the META
    /// fingerprint against the manifest's model and the parameter
    /// digest end-to-end, exactly like the trainer's restore path.
    pub fn from_checkpoint(
        manifest: &Manifest,
        engine: Arc<dyn Executor>,
        model_name: &str,
        ck: &Checkpoint,
    ) -> Result<Predictor> {
        let model = manifest.model(model_name)?.clone();
        ensure!(
            !model.predict_exe.is_empty(),
            "model '{model_name}' has no predict executable — the manifest predates the \
             inference contract"
        );
        let meta = ckpt::Meta::of(ck)?;
        ensure!(
            meta.model == model.name,
            "checkpoint is for model '{}', serving '{}'",
            meta.model,
            model.name
        );
        ensure!(
            meta.nparams as usize == model.params.len(),
            "checkpoint has {} params, model '{}' declares {}",
            meta.nparams,
            model.name,
            model.params.len()
        );
        ensure!(
            meta.nbn as usize == model.bn_order.len(),
            "checkpoint has {} bn sections, model '{}' declares {}",
            meta.nbn,
            model.name,
            model.bn_order.len()
        );

        // shapes come from the manifest; data is overwritten per section
        let mut params = manifest.load_init_params(&model)?;
        for (pi, p) in params.iter_mut().enumerate() {
            let bytes = ck.require(SEC_PARAM, pi as u16, "param section")?;
            let mut r = ByteReader::new(bytes);
            let data = r.f32s(p.data.len())?;
            r.finish()?;
            p.data = data;
        }
        ensure!(
            ckpt::params_fnv(&params) == meta.params_fnv,
            "loaded parameters do not hash to the checkpoint's digest"
        );

        let mut bn = Vec::with_capacity(model.bn_order.len());
        for (bi, bname) in model.bn_order.iter().enumerate() {
            let c = model.layer(bname).map(|l| l.channels).unwrap_or(0);
            let bytes = ck.require(SEC_BN, bi as u16, "bn section")?;
            let mut r = ByteReader::new(bytes);
            let ch = r.u32()? as usize;
            ensure!(ch == c, "bn section {bi} has {ch} channels, layer '{bname}' has {c}");
            let mean = r.f32s(ch)?;
            let var = r.f32s(ch)?;
            r.finish()?;
            bn.push((HostTensor::new(vec![c], mean), HostTensor::new(vec![c], var)));
        }
        Ok(Predictor { engine, model, params, bn, step: meta.step })
    }

    /// Load from a checkpoint file on disk.
    pub fn from_checkpoint_file(
        manifest: &Manifest,
        engine: Arc<dyn Executor>,
        model_name: &str,
        path: &std::path::Path,
    ) -> Result<Predictor> {
        let ck = ckpt::read_file(path)?;
        Predictor::from_checkpoint(manifest, engine, model_name, &ck)
            .with_context(|| format!("loading weights from {}", path.display()))
    }

    /// Flattened input size per row (C·H·W).
    pub fn in_dim(&self) -> usize {
        self.model.input_shape.iter().skip(1).product()
    }

    /// Static batch of the predict executable — the micro-batch cap.
    pub fn batch(&self) -> usize {
        self.model.batch
    }

    pub fn classes(&self) -> usize {
        self.model.num_classes
    }

    pub fn model_name(&self) -> &str {
        &self.model.name
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    /// Forward 1..=batch rows through the predict executable. Rows are
    /// padded up to the static batch shape with zeros and the padding
    /// logits discarded — callers only see their own rows.
    pub fn logits(&self, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let (b, dim, k) = (self.batch(), self.in_dim(), self.classes());
        let n = rows.len();
        ensure!(n >= 1 && n <= b, "predict got {n} rows, the static batch allows 1..={b}");
        for (i, r) in rows.iter().enumerate() {
            ensure!(
                r.len() == dim,
                "row {i} has {} values, the model input is {dim} (C·H·W)",
                r.len()
            );
        }
        let mut x = vec![0.0f32; b * dim];
        for (i, r) in rows.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(r);
        }
        let x = HostTensor::new(self.model.input_shape.clone(), x);
        let mut inputs: Vec<&HostTensor> = self.params.iter().collect();
        inputs.push(&x);
        for (m, _) in &self.bn {
            inputs.push(m);
        }
        for (_, v) in &self.bn {
            inputs.push(v);
        }
        let out = self.engine.execute(&self.model.predict_exe, &inputs)?;
        ensure!(
            !out.is_empty() && out[0].data.len() == b * k,
            "predict executable returned a malformed logits tensor"
        );
        Ok((0..n).map(|i| out[0].data[i * k..(i + 1) * k].to_vec()).collect())
    }
}

/// Server knobs (`spngd serve` flags).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    /// bind address, e.g. `127.0.0.1:8080` (port 0 for tests)
    pub addr: String,
    /// micro-batch row cap; clamped to the model's static batch
    pub max_batch: usize,
    /// coalescing window for the micro-batcher (µs)
    pub max_wait_us: u64,
    /// connection-handler pool size
    pub threads: usize,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg { addr: "127.0.0.1:8080".into(), max_batch: 0, max_wait_us: 2_000, threads: 4 }
    }
}

/// HTTP-level counters ([`queue::QueueStats`] covers the batcher).
#[derive(Default)]
struct HttpStats {
    requests: AtomicU64,
    predict_requests: AtomicU64,
    errors: AtomicU64,
}

struct Inner {
    predictor: Predictor,
    queue: Arc<queue::BatchQueue>,
    http: HttpStats,
    started: Instant,
    stop: AtomicBool,
}

/// The serving process: listener + handler pool + batcher thread.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    inner: Arc<Inner>,
    threads: usize,
}

/// Handle to a [`Server::spawn`]ed server — tests and the CLI use it to
/// find the bound port and to shut down cleanly.
pub struct ServerHandle {
    addr: SocketAddr,
    inner: Arc<Inner>,
    join: std::thread::JoinHandle<()>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain the batcher, join the accept loop.
    pub fn shutdown(self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.queue.shutdown();
        // unblock the accept loop with one throwaway connection
        let _ = TcpStream::connect(self.addr);
        let _ = self.join.join();
    }
}

impl Server {
    pub fn bind(predictor: Predictor, cfg: &ServeCfg) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr().context("local addr")?;
        let max_batch = if cfg.max_batch == 0 {
            predictor.batch()
        } else {
            cfg.max_batch.min(predictor.batch())
        };
        let queue = queue::BatchQueue::new(queue::QueueCfg {
            max_batch,
            max_wait_us: cfg.max_wait_us,
        });
        let inner = Arc::new(Inner {
            predictor,
            queue,
            http: HttpStats::default(),
            started: Instant::now(),
            stop: AtomicBool::new(false),
        });
        Ok(Server { listener, addr, inner, threads: cfg.threads.max(1) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Run the accept loop on the current thread (the CLI path). The
    /// batcher gets its own named thread; connection handlers ride a
    /// `util::pool::Pool` sized by `threads`.
    pub fn run(self) {
        let inner = self.inner.clone();
        info!(
            "serve",
            "listening on http://{} (model {}, step {}, batch {}, wait {}µs)",
            self.addr,
            inner.predictor.model_name(),
            inner.predictor.step(),
            inner.queue.cfg().max_batch,
            inner.queue.cfg().max_wait_us
        );
        let batcher_inner = inner.clone();
        let batcher = std::thread::Builder::new()
            .name("spngd-serve-batch".into())
            .spawn(move || {
                let i = batcher_inner.clone();
                batcher_inner
                    .queue
                    .run(move |rows| i.predictor.logits(rows).map_err(|e| format!("{e:#}")))
            })
            .expect("spawn batcher");

        let pool = Pool::new(self.threads);
        for stream in self.listener.incoming() {
            if inner.stop.load(Ordering::SeqCst) {
                break;
            }
            match stream {
                Ok(s) => {
                    let conn_inner = inner.clone();
                    pool.submit(move || handle_connection(s, &conn_inner));
                }
                Err(e) => {
                    warn_!("serve", "accept failed: {e}");
                }
            }
        }
        inner.queue.shutdown();
        let _ = batcher.join();
    }

    /// Run on a background thread; returns a handle with the bound
    /// address. This is the test/CI entry point (`addr` with port 0).
    pub fn spawn(self) -> ServerHandle {
        let addr = self.addr;
        let inner = self.inner.clone();
        let join = std::thread::Builder::new()
            .name("spngd-serve-accept".into())
            .spawn(move || self.run())
            .expect("spawn server");
        ServerHandle { addr, inner, join }
    }
}

/// Per-connection loop: keep-alive request/response until the peer
/// closes or errors.
fn handle_connection(stream: TcpStream, inner: &Inner) {
    let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let req = match http::read_request(&mut reader) {
            Ok(r) => r,
            Err(http::HttpError::Closed) => return,
            Err(http::HttpError::TooLarge) => {
                inner.http.errors.fetch_add(1, Ordering::Relaxed);
                let body = obj(vec![("error", Json::from("request body too large"))]);
                let _ = http::write_json(&mut writer, 413, &body);
                return;
            }
            Err(http::HttpError::Bad(why)) => {
                inner.http.errors.fetch_add(1, Ordering::Relaxed);
                let body = obj(vec![("error", Json::from(why))]);
                let _ = http::write_json(&mut writer, 400, &body);
                return;
            }
            Err(http::HttpError::Io(_)) => return,
        };
        inner.http.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let (status, body) = route(&req, inner);
        if status >= 400 {
            inner.http.errors.fetch_add(1, Ordering::Relaxed);
        }
        debug!(
            "serve",
            "{peer} {} {} -> {status} in {:.1}ms",
            req.method,
            req.path,
            t0.elapsed().as_secs_f64() * 1e3
        );
        if http::write_json(&mut writer, status, &body).is_err() {
            return;
        }
    }
}

/// Typed routing table.
fn route(req: &http::Request, inner: &Inner) -> (u16, Json) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (200, health_body(inner)),
        ("GET", "/v1/stats") => (200, stats_body(inner)),
        ("POST", "/v1/predict") => predict(req, inner),
        ("GET", "/v1/predict") | ("POST", "/healthz") | ("POST", "/v1/stats") => {
            (405, obj(vec![("error", Json::from("method not allowed"))]))
        }
        _ => (404, obj(vec![("error", Json::from("no such route"))])),
    }
}

fn health_body(inner: &Inner) -> Json {
    obj(vec![
        ("ok", Json::from(true)),
        ("model", Json::from(inner.predictor.model_name())),
        ("step", Json::from(inner.predictor.step() as usize)),
        ("classes", Json::from(inner.predictor.classes())),
        ("in_dim", Json::from(inner.predictor.in_dim())),
        ("max_batch", Json::from(inner.queue.cfg().max_batch)),
    ])
}

fn stats_body(inner: &Inner) -> Json {
    let q = &inner.queue.stats;
    let ld = |a: &AtomicU64| Json::from(a.load(Ordering::Relaxed) as usize);
    obj(vec![
        ("uptime_s", Json::from(inner.started.elapsed().as_secs_f64())),
        ("requests", ld(&inner.http.requests)),
        ("predict_requests", ld(&inner.http.predict_requests)),
        ("errors", ld(&inner.http.errors)),
        ("batches", ld(&q.batches)),
        ("rows", ld(&q.rows)),
        ("full_flushes", ld(&q.full_flushes)),
        ("timeout_flushes", ld(&q.timeout_flushes)),
        ("queue_wait_us", ld(&q.queue_wait_us)),
        ("forward_us", ld(&q.forward_us)),
    ])
}

/// `POST /v1/predict`: `{"x": [[...], ...]}` (or a single flat row) →
/// `{"logits": [[...], ...], "argmax": [...]}`.
fn predict(req: &http::Request, inner: &Inner) -> (u16, Json) {
    let _span = obs::span("serve_predict", Cat::Data);
    inner.http.predict_requests.fetch_add(1, Ordering::Relaxed);
    let bad = |why: &str| (400, obj(vec![("error", Json::from(why))]));
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return bad("body is not utf-8");
    };
    let Ok(v) = Json::parse(text) else {
        return bad("body is not valid JSON");
    };
    let x = v.get("x");
    let Some(outer) = x.as_arr() else {
        return bad("missing \"x\": expected an array of rows (or one flat row)");
    };
    // accept [[row], [row]] and a bare [row] of numbers
    let rows: Vec<Vec<f32>> = if outer.iter().all(|e| e.as_f64().is_some()) && !outer.is_empty() {
        vec![outer.iter().map(|e| e.as_f64().unwrap_or(0.0) as f32).collect()]
    } else {
        let mut rows = Vec::with_capacity(outer.len());
        for e in outer {
            let Some(row) = e.as_arr() else {
                return bad("\"x\" rows must be arrays of numbers");
            };
            let mut out = Vec::with_capacity(row.len());
            for n in row {
                let Some(f) = n.as_f64() else {
                    return bad("\"x\" rows must be arrays of numbers");
                };
                out.push(f as f32);
            }
            rows.push(out);
        }
        rows
    };
    if rows.is_empty() {
        return bad("\"x\" is empty");
    }
    let dim = inner.predictor.in_dim();
    if rows.iter().any(|r| r.len() != dim) {
        return bad("every row must have C\u{b7}H\u{b7}W values (see /healthz in_dim)");
    }
    let ticket = match inner.queue.enqueue(rows) {
        Ok(t) => t,
        Err(e) => return (503, obj(vec![("error", Json::from(e))])),
    };
    match ticket.wait() {
        Ok(logits) => {
            let argmax: Vec<Json> = logits
                .iter()
                .map(|row| {
                    let mut best = 0usize;
                    for (i, v) in row.iter().enumerate() {
                        if *v > row[best] {
                            best = i;
                        }
                    }
                    Json::from(best)
                })
                .collect();
            let lj = Json::Arr(
                logits
                    .into_iter()
                    .map(|row| {
                        Json::Arr(row.into_iter().map(|v| Json::from(v as f64)).collect())
                    })
                    .collect(),
            );
            (200, obj(vec![("logits", lj), ("argmax", Json::Arr(argmax))]))
        }
        Err(e) => (500, obj(vec![("error", Json::from(e))])),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TrainerBuilder;
    use std::io::{BufRead, Read, Write};

    /// A checkpointed tiny model straight off the trainer (step 0 —
    /// weight values don't matter for the serving contract, fidelity is
    /// `tests/ckpt.rs`'s job).
    fn tiny_predictor() -> (Arc<Manifest>, Arc<dyn Executor>, Predictor) {
        let (manifest, engine) = crate::harness::load_runtime_native().unwrap();
        let mut tr = TrainerBuilder::new("convnet_tiny")
            .runtime(manifest.clone(), engine.clone())
            .optimizer(crate::optim::sgd())
            .workers(1)
            .dataset_len(256)
            .seed(7)
            .build()
            .unwrap();
        let ck = tr.checkpoint().unwrap();
        let p =
            Predictor::from_checkpoint(&manifest, engine.clone(), "convnet_tiny", &ck).unwrap();
        (manifest, engine, p)
    }

    fn det_rows(n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|r| {
                (0..dim).map(|i| ((i * 37 + r * 101) % 29) as f32 / 29.0 - 0.5).collect()
            })
            .collect()
    }

    #[test]
    fn predictor_logits_match_a_direct_executor_forward() {
        let (_manifest, engine, p) = tiny_predictor();
        let (b, dim, k) = (p.batch(), p.in_dim(), p.classes());
        let rows = det_rows(3, dim);
        let got = p.logits(&rows).unwrap();

        // hand-build the padded predict call the way a caller without the
        // Predictor would: params…, x, bn_means…, bn_vars…
        let mut x = vec![0.0f32; b * dim];
        for (i, r) in rows.iter().enumerate() {
            x[i * dim..(i + 1) * dim].copy_from_slice(r);
        }
        let x = HostTensor::new(p.model.input_shape.clone(), x);
        let mut inputs: Vec<&HostTensor> = p.params.iter().collect();
        inputs.push(&x);
        for (m, _) in &p.bn {
            inputs.push(m);
        }
        for (_, v) in &p.bn {
            inputs.push(v);
        }
        let out = engine.execute(&p.model.predict_exe, &inputs).unwrap();
        let want: Vec<Vec<f32>> =
            (0..rows.len()).map(|i| out[0].data[i * k..(i + 1) * k].to_vec()).collect();
        assert_eq!(got, want, "Predictor must be bitwise equal to a direct executor forward");

        // contract errors: wrong row width, empty, over the static batch
        assert!(p.logits(&[vec![0.0; dim + 1]]).is_err());
        assert!(p.logits(&[]).is_err());
        assert!(p.logits(&det_rows(b + 1, dim)).is_err());
    }

    // -- minimal HTTP client for the socket tests --------------------

    fn read_response(r: &mut impl BufRead) -> (u16, Json) {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
        let mut clen = 0usize;
        loop {
            let mut h = String::new();
            r.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                if k.eq_ignore_ascii_case("content-length") {
                    clen = v.trim().parse().unwrap();
                }
            }
        }
        let mut body = vec![0u8; clen];
        r.read_exact(&mut body).unwrap();
        (status, Json::parse(std::str::from_utf8(&body).unwrap()).unwrap())
    }

    fn http(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
        let mut s = TcpStream::connect(addr).unwrap();
        let body = body.unwrap_or("");
        write!(
            s,
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        s.flush().unwrap();
        read_response(&mut BufReader::new(s))
    }

    #[test]
    fn server_routes_predict_health_stats_and_errors_over_real_sockets() {
        let (_m, _e, p) = tiny_predictor();
        let dim = p.in_dim();
        let k = p.classes();
        let server = Server::bind(
            p,
            &ServeCfg {
                addr: "127.0.0.1:0".into(),
                max_batch: 0,
                max_wait_us: 1_000, // lone requests must not dawdle
                threads: 2,
            },
        )
        .unwrap();
        let h = server.spawn();
        let addr = h.addr();

        let (st, health) = http(addr, "GET", "/healthz", None);
        assert_eq!(st, 200);
        assert_eq!(health.get("ok").as_f64(), None); // bool, not number
        assert_eq!(health.get("model").as_str(), Some("convnet_tiny"));
        assert_eq!(health.get("in_dim").as_usize(), Some(dim));

        let row = &det_rows(1, dim)[0];
        let xs: Vec<String> = row.iter().map(|v| format!("{v}")).collect();
        let body = format!("{{\"x\":[[{}]]}}", xs.join(","));
        let (st, resp) = http(addr, "POST", "/v1/predict", Some(&body));
        assert_eq!(st, 200, "{resp:?}");
        let logits = resp.get("logits").as_arr().unwrap();
        assert_eq!(logits.len(), 1);
        let l0 = logits[0].as_arr().unwrap();
        assert_eq!(l0.len(), k);
        let vals: Vec<f64> = l0.iter().map(|v| v.as_f64().unwrap()).collect();
        let mut best = 0usize;
        for (i, v) in vals.iter().enumerate() {
            if *v > vals[best] {
                best = i;
            }
        }
        assert_eq!(resp.get("argmax").as_arr().unwrap()[0].as_usize(), Some(best));

        // a bare flat row is accepted too
        let flat = format!("{{\"x\":[{}]}}", xs.join(","));
        let (st, resp2) = http(addr, "POST", "/v1/predict", Some(&flat));
        assert_eq!(st, 200);
        assert_eq!(resp2.get("logits").as_arr().unwrap().len(), 1);

        // typed failures
        assert_eq!(http(addr, "GET", "/nope", None).0, 404);
        assert_eq!(http(addr, "POST", "/healthz", None).0, 405);
        assert_eq!(http(addr, "POST", "/v1/predict", Some("not json")).0, 400);
        assert_eq!(http(addr, "POST", "/v1/predict", Some("{\"x\":[]}")).0, 400);
        assert_eq!(http(addr, "POST", "/v1/predict", Some("{\"x\":[[1.0]]}")).0, 400);

        let (st, stats) = http(addr, "GET", "/v1/stats", None);
        assert_eq!(st, 200);
        assert!(stats.get("requests").as_usize().unwrap() >= 8);
        assert_eq!(stats.get("predict_requests").as_usize(), Some(5));
        assert!(stats.get("errors").as_usize().unwrap() >= 5);
        assert_eq!(stats.get("rows").as_usize(), Some(2));
        h.shutdown();
    }

    #[test]
    fn concurrent_predicts_coalesce_into_one_forward_batch() {
        let (_m, _e, p) = tiny_predictor();
        let dim = p.in_dim();
        let server = Server::bind(
            p,
            &ServeCfg {
                addr: "127.0.0.1:0".into(),
                max_batch: 2,
                // far above scheduling noise: the only way both clients
                // return quickly is the *full* flush of a shared batch
                max_wait_us: 5_000_000,
                threads: 4,
            },
        )
        .unwrap();
        let queue = server.inner.queue.clone();
        let h = server.spawn();
        let addr = h.addr();

        let rows = det_rows(2, dim);
        let mk_body = |r: &Vec<f32>| {
            let xs: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
            format!("{{\"x\":[[{}]]}}", xs.join(","))
        };
        let (b0, b1) = (mk_body(&rows[0]), mk_body(&rows[1]));
        let t0 = std::thread::spawn(move || http(addr, "POST", "/v1/predict", Some(&b0)));
        let t1 = std::thread::spawn(move || http(addr, "POST", "/v1/predict", Some(&b1)));
        let (s0, r0) = t0.join().unwrap();
        let (s1, r1) = t1.join().unwrap();
        assert_eq!((s0, s1), (200, 200), "{r0:?} {r1:?}");

        assert_eq!(queue.stats.batches.load(Ordering::Relaxed), 1);
        assert_eq!(queue.stats.rows.load(Ordering::Relaxed), 2);
        assert_eq!(queue.stats.full_flushes.load(Ordering::Relaxed), 1);
        h.shutdown();
    }
}
