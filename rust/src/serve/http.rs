//! Dependency-free HTTP/1.1 — just enough protocol for the inference
//! endpoints, on `std::io` traits so tests can drive it with in-memory
//! cursors. Parse-don't-panic: every malformed input surfaces as a
//! typed [`HttpError`] the connection handler maps to a status code,
//! and header/body sizes are capped before allocation (the same
//! total-parser discipline as `ckpt::format`).

use std::io::{BufRead, Read, Write};

use crate::util::json::Json;

/// Request line + headers cap (bytes).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Body cap (bytes) — comfortably fits a full model batch of f32 rows
/// in JSON while bounding a hostile Content-Length.
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// One parsed request. Headers beyond Content-Length are dropped — the
/// routes don't consume them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

#[derive(Debug)]
pub enum HttpError {
    /// peer closed between requests — the clean keep-alive exit
    Closed,
    /// protocol violation → 400, then drop the connection
    Bad(&'static str),
    /// declared body over [`MAX_BODY_BYTES`] → 413
    TooLarge,
    Io(std::io::Error),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Closed => write!(f, "connection closed"),
            HttpError::Bad(why) => write!(f, "bad request: {why}"),
            HttpError::TooLarge => write!(f, "request body too large"),
            HttpError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

fn read_line_capped(
    r: &mut impl BufRead,
    budget: &mut usize,
    what: &'static str,
) -> Result<Option<String>, HttpError> {
    let mut line = String::new();
    let n = match r.read_line(&mut line) {
        Ok(n) => n,
        // non-UTF-8 header bytes are a protocol violation, not an I/O fault
        Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
            return Err(HttpError::Bad("non-utf8 bytes in headers"))
        }
        Err(e) => return Err(HttpError::Io(e)),
    };
    if n == 0 {
        return Ok(None);
    }
    *budget = budget.checked_sub(n).ok_or(HttpError::Bad(what))?;
    Ok(Some(line.trim_end_matches(['\r', '\n']).to_string()))
}

/// Read one request. [`HttpError::Closed`] when the peer hangs up
/// before the first byte (the keep-alive loop's normal exit).
pub fn read_request(r: &mut impl BufRead) -> Result<Request, HttpError> {
    let mut budget = MAX_HEADER_BYTES;
    let first = read_line_capped(r, &mut budget, "request line too long")?
        .ok_or(HttpError::Closed)?;
    let mut parts = first.split_whitespace();
    let method = parts.next().filter(|m| !m.is_empty()).ok_or(HttpError::Bad("empty request line"))?;
    let path = parts.next().ok_or(HttpError::Bad("missing request path"))?;
    let version = parts.next().ok_or(HttpError::Bad("missing protocol version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::Bad("not an HTTP/1.x request"));
    }

    let mut content_length = 0usize;
    loop {
        let line = read_line_capped(r, &mut budget, "headers too long")?
            .ok_or(HttpError::Bad("eof inside headers"))?;
        if line.is_empty() {
            break;
        }
        let (k, v) = line.split_once(':').ok_or(HttpError::Bad("malformed header"))?;
        if k.eq_ignore_ascii_case("content-length") {
            content_length =
                v.trim().parse().map_err(|_| HttpError::Bad("unparseable content-length"))?;
        } else if k.eq_ignore_ascii_case("transfer-encoding") {
            return Err(HttpError::Bad("chunked bodies not supported"));
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(HttpError::TooLarge);
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).map_err(HttpError::Io)?;
    Ok(Request { method: method.to_string(), path: path.to_string(), body })
}

pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Write one JSON response (keep-alive; Content-Length framed).
pub fn write_json(w: &mut impl Write, status: u16, body: &Json) -> std::io::Result<()> {
    let b = body.to_string();
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: keep-alive\r\n\r\n{b}",
        reason(status),
        b.len()
    )?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::obj;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body_and_keepalive_sequencing() {
        let wire = b"POST /v1/predict HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"x\":1}GET /healthz HTTP/1.1\r\n\r\n";
        let mut r = Cursor::new(&wire[..]);
        let a = read_request(&mut r).unwrap();
        assert_eq!(a.method, "POST");
        assert_eq!(a.path, "/v1/predict");
        assert_eq!(a.body, b"{\"x\":1}");
        let b = read_request(&mut r).unwrap();
        assert_eq!((b.method.as_str(), b.path.as_str()), ("GET", "/healthz"));
        assert!(b.body.is_empty());
        // stream exhausted → clean Closed
        assert!(matches!(read_request(&mut r), Err(HttpError::Closed)));
    }

    #[test]
    fn malformed_requests_are_typed_errors_never_panics() {
        let cases: &[&[u8]] = &[
            b"GARBAGE\r\n\r\n",                                        // no path/version
            b"GET /x SPDY/3\r\n\r\n",                                  // wrong protocol
            b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n",               // no colon
            b"POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n",       // bad length
            b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", // chunked
            b"GET /x HTTP/1.1\r\nIncomplete",                          // eof in headers
            b"\xff\xfe\x00GET",                                        // byte soup
        ];
        for c in cases {
            assert!(
                matches!(read_request(&mut Cursor::new(*c)), Err(HttpError::Bad(_))),
                "case {:?}",
                String::from_utf8_lossy(c)
            );
        }
        // declared body larger than the cap → TooLarge, with NO allocation
        let big = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", usize::MAX / 2);
        assert!(matches!(
            read_request(&mut Cursor::new(big.as_bytes())),
            Err(HttpError::TooLarge)
        ));
        // oversized header block
        let long = format!("GET /x HTTP/1.1\r\nPad: {}\r\n\r\n", "a".repeat(MAX_HEADER_BYTES));
        assert!(matches!(
            read_request(&mut Cursor::new(long.as_bytes())),
            Err(HttpError::Bad(_))
        ));
    }

    #[test]
    fn response_is_length_framed_json() {
        let mut out = Vec::new();
        write_json(&mut out, 200, &obj(vec![("ok", Json::from(true))])).unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 200 OK\r\n"), "{s}");
        let body = s.split("\r\n\r\n").nth(1).unwrap();
        assert_eq!(body, r#"{"ok":true}"#);
        assert!(s.contains(&format!("Content-Length: {}", body.len())));
    }
}
