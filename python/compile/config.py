"""Model configurations for the L2 build path.

A model is a flat op program (list of layer specs). Residual blocks are
expressed with Save/Add ops; Add may carry a projection (conv+bn) applied
to the saved tensor, which is how ResNet downsample shortcuts appear.

The `convnet` family mirrors ResNet's layer taxonomy (Conv/BN/FC — the
paper's 107 K-FAC layers for ResNet-50) at CPU-tractable width/depth; see
DESIGN.md section 4 for the substitution rationale.
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class Conv:
    name: str
    cin: int
    cout: int
    k: int
    stride: int = 1
    pad: int = 0


@dataclass(frozen=True)
class Bn:
    name: str
    c: int


@dataclass(frozen=True)
class Relu:
    name: str


@dataclass(frozen=True)
class Fc:
    name: str
    din: int
    dout: int


@dataclass(frozen=True)
class GlobalPool:
    name: str


@dataclass(frozen=True)
class Flatten:
    name: str


@dataclass(frozen=True)
class Save:
    name: str


@dataclass(frozen=True)
class Add:
    name: str
    from_save: str
    # optional projection on the shortcut: (Conv, Bn)
    proj_conv: Optional[Conv] = None
    proj_bn: Optional[Bn] = None


@dataclass
class ModelCfg:
    name: str
    in_shape: Tuple[int, int, int]  # (C, H, W)
    num_classes: int
    batch: int  # per-worker batch (the paper uses 32/GPU)
    ops: List[object] = field(default_factory=list)

    def conv_layers(self):
        out = [op for op in self.ops if isinstance(op, Conv)]
        for op in self.ops:
            if isinstance(op, Add) and op.proj_conv is not None:
                out.append(op.proj_conv)
        return out

    def bn_layers(self):
        out = [op for op in self.ops if isinstance(op, Bn)]
        for op in self.ops:
            if isinstance(op, Add) and op.proj_bn is not None:
                out.append(op.proj_bn)
        return out

    def fc_layers(self):
        return [op for op in self.ops if isinstance(op, Fc)]


def _basic_block(prefix: str, cin: int, cout: int, stride: int):
    """ResNet basic block: conv-bn-relu-conv-bn + shortcut, relu."""
    ops = [Save(f"{prefix}.in")]
    ops += [
        Conv(f"{prefix}.conv1", cin, cout, 3, stride, 1),
        Bn(f"{prefix}.bn1", cout),
        Relu(f"{prefix}.relu1"),
        Conv(f"{prefix}.conv2", cout, cout, 3, 1, 1),
        Bn(f"{prefix}.bn2", cout),
    ]
    if stride != 1 or cin != cout:
        ops.append(
            Add(
                f"{prefix}.add",
                f"{prefix}.in",
                proj_conv=Conv(f"{prefix}.proj", cin, cout, 1, stride, 0),
                proj_bn=Bn(f"{prefix}.projbn", cout),
            )
        )
    else:
        ops.append(Add(f"{prefix}.add", f"{prefix}.in"))
    ops.append(Relu(f"{prefix}.relu2"))
    return ops


def convnet(
    name="convnet",
    width=16,
    img=16,
    blocks=(2, 2),
    num_classes=10,
    batch=32,
) -> ModelCfg:
    """ResNet-style ConvNet: stem + stages of basic blocks + GAP + FC."""
    ops = [
        Conv("stem.conv", 3, width, 3, 1, 1),
        Bn("stem.bn", width),
        Relu("stem.relu"),
    ]
    cin = width
    for s, nblocks in enumerate(blocks):
        cout = width * (2**s)
        for b in range(nblocks):
            stride = 2 if (s > 0 and b == 0) else 1
            ops += _basic_block(f"s{s}b{b}", cin, cout, stride)
            cin = cout
    ops += [
        GlobalPool("gap"),
        Flatten("flat"),
        Fc("fc", cin, num_classes),
    ]
    return ModelCfg(name, (3, img, img), num_classes, batch, ops)


def convnet_small(batch=32) -> ModelCfg:
    """The end-to-end example model (~60k params, 21 K-FAC layers)."""
    return convnet("convnet_small", width=16, img=16, blocks=(2, 2), batch=batch)


def convnet_tiny(batch=8) -> ModelCfg:
    """Fast config for pytest."""
    return convnet("convnet_tiny", width=8, img=8, blocks=(1, 1), batch=batch)


def mlp(name="mlp", dims=(192, 128, 64), num_classes=10, batch=32, img=8) -> ModelCfg:
    """FC-only model for the quickstart (input flattened 3*img*img)."""
    assert dims[0] == 3 * img * img
    ops = [Flatten("flat")]
    d = dims[0]
    for i, h in enumerate(dims[1:]):
        ops += [Fc(f"fc{i}", d, h), Relu(f"relu{i}")]
        d = h
    ops += [Fc("head", d, num_classes)]
    return ModelCfg(name, (3, img, img), num_classes, batch, ops)


MODELS = {
    "convnet_small": convnet_small,
    "convnet_tiny": convnet_tiny,
    "mlp": mlp,
}
