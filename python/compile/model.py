"""L2: the JAX model — forward/backward with K-FAC statistics capture.

This module builds every function the rust coordinator executes:

  step_emp   (params, x, t)        -> loss, ncorrect, grads, taps, bn stats
  step_1mc   (params, x, t, seed)  -> same, with Fisher taps from a
                                      Monte-Carlo label sample (extra bwd)
  eval_batch (params, x, t, bn...) -> loss, ncorrect (running BN stats)

Per-sample output gradients (the G-factor inputs) are obtained with the
probe trick: a zero "probe" tensor is added to each layer's pre-activation
output; the gradient of the mean loss w.r.t. the probe is exactly
(1/B) * per-sample d log p / d s, so scaling by -B recovers per-sample
gradients of log p without per-sample vmap backward passes. This is the
"statistics during the ordinary backward pass" trick of Sec. 4.1
(empirical Fisher with no extra backward).

Factor *construction* (im2col + syrk) happens in separate small artifacts
(see aot.py) so the stale-statistics scheduler in rust can skip it
per-layer (Sec. 4.3); this module only emits the taps those artifacts
consume.
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import config as C

BN_EPS = 1e-5


# --------------------------------------------------------------- params


def param_order(cfg: C.ModelCfg):
    """Deterministic parameter order: follows op-program order; Add
    projections contribute after the block's own ops; BN contributes
    (gamma, beta)."""
    names = []
    for op in cfg.ops:
        if isinstance(op, C.Conv):
            names.append((op.name + ".w", op))
        elif isinstance(op, C.Fc):
            names.append((op.name + ".w", op))
        elif isinstance(op, C.Bn):
            names.append((op.name + ".gamma", op))
            names.append((op.name + ".beta", op))
        elif isinstance(op, C.Add) and op.proj_conv is not None:
            names.append((op.proj_conv.name + ".w", op.proj_conv))
            names.append((op.proj_bn.name + ".gamma", op.proj_bn))
            names.append((op.proj_bn.name + ".beta", op.proj_bn))
    return names


def param_shapes(cfg: C.ModelCfg):
    shapes = []
    for name, op in param_order(cfg):
        if isinstance(op, C.Conv):
            shapes.append((name, (op.cout, op.cin, op.k, op.k)))
        elif isinstance(op, C.Fc):
            shapes.append((name, (op.dout, op.din)))
        elif isinstance(op, C.Bn):
            shapes.append((name, (op.c,)))
    return shapes


def init_params(cfg: C.ModelCfg, seed=0):
    """HeNormal for Conv/FC (as the paper: Chainer HeNormal), BN gamma=1,
    beta=0. Returns list of arrays in param order."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".gamma"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".beta"):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            # HeNormal: std = sqrt(2 / fan_in)
            fan_in = 1
            for d in shape[1:]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            out.append(std * jax.random.normal(sub, shape, jnp.float32))
    return out


def params_to_dict(cfg, params_list):
    names = [n for n, _ in param_shapes(cfg)]
    assert len(names) == len(params_list)
    return dict(zip(names, params_list))


# ----------------------------------------------------------- kfac meta


def kfac_layers(cfg: C.ModelCfg):
    """Ordered list of (name, kind, op) for layers with Kronecker factors
    (conv/fc) or unit-BN Fisher (bn). Order = op-program order with Add
    projections in place."""
    out = []
    for op in cfg.ops:
        if isinstance(op, C.Conv):
            out.append((op.name, "conv", op))
        elif isinstance(op, C.Fc):
            out.append((op.name, "fc", op))
        elif isinstance(op, C.Bn):
            out.append((op.name, "bn", op))
        elif isinstance(op, C.Add) and op.proj_conv is not None:
            out.append((op.proj_conv.name, "conv", op.proj_conv))
            out.append((op.proj_bn.name, "bn", op.proj_bn))
    return out


def _spatial_out(op: C.Conv, h, w):
    ho = (h + 2 * op.pad - op.k) // op.stride + 1
    wo = (w + 2 * op.pad - op.k) // op.stride + 1
    return ho, wo


def layer_geometry(cfg: C.ModelCfg):
    """Static shapes for every K-FAC layer: tap shapes, factor dims, grad
    matrix shape. Traces the op program symbolically (shapes only)."""
    b = cfg.batch
    c, h, w = cfg.in_shape
    geo = {}
    saved = {}

    def record_conv(op, cin, hh, ww):
        ho, wo = _spatial_out(op, hh, ww)
        geo[op.name] = dict(
            kind="conv",
            a_tap=(b, cin, hh, ww),
            g_tap=(b, op.cout, ho, wo),
            a_dim=cin * op.k * op.k,
            g_dim=op.cout,
            grad_shape=(op.cout, cin * op.k * op.k),
            conv_sig=(cin, hh, ww, op.k, op.stride, op.pad),
            spatial=ho * wo,
        )
        return op.cout, ho, wo

    flat_d = None
    for op in cfg.ops:
        if isinstance(op, C.Save):
            saved[op.name] = (c, h, w)
        elif isinstance(op, C.Conv):
            c, h, w = record_conv(op, c, h, w)
        elif isinstance(op, C.Bn):
            geo[op.name] = dict(kind="bn", c=op.c, tap=(b, op.c))
        elif isinstance(op, C.Relu):
            pass
        elif isinstance(op, C.Add):
            sc, sh, sw = saved[op.from_save]
            if op.proj_conv is not None:
                pc, ph, pw = record_conv(op.proj_conv, sc, sh, sw)
                geo[op.proj_bn.name] = dict(
                    kind="bn", c=op.proj_bn.c, tap=(b, op.proj_bn.c)
                )
                assert (pc, ph, pw) == (c, h, w), "projection shape mismatch"
        elif isinstance(op, C.GlobalPool):
            h, w = 1, 1
        elif isinstance(op, C.Flatten):
            flat_d = c * h * w
        elif isinstance(op, C.Fc):
            assert flat_d == op.din, f"{op.name}: {flat_d} != {op.din}"
            geo[op.name] = dict(
                kind="fc",
                a_tap=(b, op.din),
                g_tap=(b, op.dout),
                a_dim=op.din,
                g_dim=op.dout,
                grad_shape=(op.dout, op.din),
            )
            flat_d = op.dout
    return geo


# ------------------------------------------------------------- forward


def _conv_apply(h, w, op: C.Conv):
    return lax.conv_general_dilated(
        h,
        w,
        window_strides=(op.stride, op.stride),
        padding=[(op.pad, op.pad), (op.pad, op.pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def forward(cfg, pdict, probes, x, bn_running=None):
    """Run the op program.

    probes: dict layer-name -> zero tensor added to the pre-activation
            (conv/fc outputs, bn outputs). Pass {} for no probes (eval).
    bn_running: dict bn-name -> (mean, var) to use instead of batch stats
            (eval mode). None -> batch stats (training mode).

    Returns (logits, taps, bn_batch_stats) where taps has, per conv/fc
    layer, 'a' (input activation) and per bn layer 'xhat'.
    """
    taps = {}
    bn_stats = {}
    saved = {}
    h = x

    def apply_conv(h, op):
        taps[op.name + ".a"] = h
        s = _conv_apply(h, pdict[op.name + ".w"], op)
        if op.name in probes:
            s = s + probes[op.name]
        return s

    def apply_bn(h, op):
        if bn_running is not None:
            mean, var = bn_running[op.name]
        else:
            mean = jnp.mean(h, axis=(0, 2, 3))
            var = jnp.var(h, axis=(0, 2, 3))
            bn_stats[op.name] = (mean, var)
        xhat = (h - mean[None, :, None, None]) * lax.rsqrt(
            var[None, :, None, None] + BN_EPS
        )
        taps[op.name + ".xhat"] = xhat
        s = (
            pdict[op.name + ".gamma"][None, :, None, None] * xhat
            + pdict[op.name + ".beta"][None, :, None, None]
        )
        if op.name in probes:
            s = s + probes[op.name]
        return s

    for op in cfg.ops:
        if isinstance(op, C.Save):
            saved[op.name] = h
        elif isinstance(op, C.Conv):
            h = apply_conv(h, op)
        elif isinstance(op, C.Bn):
            h = apply_bn(h, op)
        elif isinstance(op, C.Relu):
            h = jax.nn.relu(h)
        elif isinstance(op, C.Add):
            sc = saved[op.from_save]
            if op.proj_conv is not None:
                sc = apply_conv(sc, op.proj_conv)
                sc = apply_bn(sc, op.proj_bn)
            h = h + sc
        elif isinstance(op, C.GlobalPool):
            h = jnp.mean(h, axis=(2, 3), keepdims=True)
        elif isinstance(op, C.Flatten):
            h = h.reshape(h.shape[0], -1)
        elif isinstance(op, C.Fc):
            taps[op.name + ".a"] = h
            s = h @ pdict[op.name + ".w"].T
            if op.name in probes:
                s = s + probes[op.name]
            h = s
        else:
            raise TypeError(f"unknown op {op}")
    return h, taps, bn_stats


def _zero_probes(cfg, geo):
    probes = {}
    for name, kind, op in kfac_layers(cfg):
        if kind == "bn":
            # probe on bn output: same shape as the conv output feeding it
            # — recover it from the xhat tap shape at trace time; easier:
            # bn output shape equals its input, which we do not know here,
            # so bn probes are created inside make_step from a shape probe.
            continue
        probes[name] = jnp.zeros(geo[name]["g_tap"], jnp.float32)
    return probes


def _loss_from_logits(logits, t):
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.sum(t * logp, axis=-1))
    ncorrect = jnp.sum(
        (jnp.argmax(logits, -1) == jnp.argmax(t, -1)).astype(jnp.float32)
    )
    return loss, ncorrect


def _bn_probe_shapes(cfg, geo):
    """BN probe shape = shape of the tensor the BN normalizes = the g_tap
    of the conv feeding it. We find it by symbolic pairing: in the op
    program a Bn always follows its Conv (and proj bn follows proj conv)."""
    shapes = {}
    prev_conv = None
    for op in cfg.ops:
        if isinstance(op, C.Conv):
            prev_conv = op
        elif isinstance(op, C.Bn):
            assert prev_conv is not None, f"bn {op.name} without conv"
            shapes[op.name] = geo[prev_conv.name]["g_tap"]
        elif isinstance(op, C.Add) and op.proj_conv is not None:
            shapes[op.proj_bn.name] = geo[op.proj_conv.name]["g_tap"]
    return shapes


def make_step(cfg: C.ModelCfg, fisher="emp"):
    """Build the per-step function.

    Inputs:  params (list in param order), x (B,C,H,W), t (B,K) soft
             one-hot, and for fisher='1mc' a scalar uint32 seed.
    Outputs (ordered, see aot.manifest):
      loss, ncorrect,
      grads (one per param, param order),
      per conv/fc K-FAC layer (kfac order): a_tap, g_tap,
      per bn layer (kfac order): g_gamma (B,C), g_beta (B,C),
      per bn layer (kfac order): batch mean (C,), batch var (C,).
    """
    geo = layer_geometry(cfg)
    bn_probe_shapes = _bn_probe_shapes(cfg, geo)
    b = cfg.batch
    klayers = kfac_layers(cfg)

    def build_probes():
        probes = {}
        for name, kind, _ in klayers:
            if kind == "bn":
                probes[name] = jnp.zeros(bn_probe_shapes[name], jnp.float32)
            else:
                probes[name] = jnp.zeros(geo[name]["g_tap"], jnp.float32)
        return probes

    def loss_fn(params_list, probes, x, t):
        pdict = params_to_dict(cfg, params_list)
        logits, taps, bn_stats = forward(cfg, pdict, probes, x)
        loss, ncorrect = _loss_from_logits(logits, t)
        return loss, (logits, taps, bn_stats, ncorrect)

    def collect_outputs(gparams, gprobes, taps, bn_stats, loss, ncorrect):
        outs = [loss, ncorrect]
        outs.extend(gparams)
        for name, kind, _ in klayers:
            if kind == "bn":
                continue
            gs = gprobes[name] * b  # per-sample dlogp/ds (sign-flipped)
            outs.append(taps[name + ".a"])
            outs.append(gs)
        for name, kind, _ in klayers:
            if kind != "bn":
                continue
            gs = gprobes[name] * b
            xhat = taps[name + ".xhat"]
            outs.append(jnp.sum(gs * xhat, axis=(2, 3)))  # g_gamma (B,C)
            outs.append(jnp.sum(gs, axis=(2, 3)))  # g_beta (B,C)
        for name, kind, _ in klayers:
            if kind != "bn":
                continue
            mean, var = bn_stats[name]
            outs.append(mean)
            outs.append(var)
        return tuple(outs)

    if fisher == "emp":

        def step(params_list, x, t):
            probes = build_probes()
            (loss, (logits, taps, bn_stats, ncorrect)), (gp, gprobe) = (
                jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
                    params_list, probes, x, t
                )
            )
            return collect_outputs(gp, gprobe, taps, bn_stats, loss, ncorrect)

        return step

    elif fisher == "1mc":

        def step(params_list, x, t, seed):
            probes = build_probes()
            # backward 1: gradients w.r.t. params for the *true* labels
            (loss, (logits, taps, bn_stats, ncorrect)), gp = (
                jax.value_and_grad(loss_fn, argnums=0, has_aux=True)(
                    params_list, probes, x, t
                )
            )
            # sample y ~ p_theta(y|x); backward 2: probe grads for the
            # sampled labels (the Monte-Carlo Fisher estimate, Eq. 5)
            key = jax.random.PRNGKey(seed.astype(jnp.uint32))
            y = jax.random.categorical(key, logits, axis=-1)
            t_mc = jax.nn.one_hot(y, cfg.num_classes, dtype=jnp.float32)
            (_, _), gprobe = jax.value_and_grad(
                loss_fn, argnums=1, has_aux=True
            )(params_list, probes, x, t_mc)
            return collect_outputs(gp, gprobe, taps, bn_stats, loss, ncorrect)

        return step

    raise ValueError(f"unknown fisher mode {fisher}")


def make_eval(cfg: C.ModelCfg):
    """eval_batch(params, x, t, bn_means..., bn_vars...) -> loss, ncorrect.

    Uses running BN statistics maintained by the rust coordinator.
    """
    bn_names = [n for n, k, _ in kfac_layers(cfg) if k == "bn"]

    def eval_batch(params_list, x, t, bn_means, bn_vars):
        pdict = params_to_dict(cfg, params_list)
        bn_running = {
            n: (bn_means[i], bn_vars[i]) for i, n in enumerate(bn_names)
        }
        logits, _, _ = forward(cfg, pdict, {}, x, bn_running=bn_running)
        loss, ncorrect = _loss_from_logits(logits, t)
        return loss, ncorrect

    return eval_batch
