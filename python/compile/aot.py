"""AOT pipeline: lower every L2/L1 function to HLO text + write the
manifest the rust coordinator consumes.

Interchange is HLO *text* (not serialized HloModuleProto): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Artifacts (per model):
  step_<model>_emp.hlo.txt     fwd/bwd + taps, empirical Fisher
  step_<model>_1mc.hlo.txt     fwd/bwd + taps, 1-sample MC Fisher
  eval_<model>.hlo.txt         validation loss/acc with running BN stats
  init_<model>.bin             HeNormal initial parameters (raw f32 LE)
Shared (deduplicated across models by signature):
  factor_conv_a_*.hlo.txt      im2col + syrk  (Pallas)    A for conv
  factor_g_r<r>c<c>.hlo.txt    syrk           (Pallas)    G, fc A
  bn_inv_<C>.hlo.txt           unit-BN damped closed-form inverse
  bn_full_<C>.hlo.txt          full (2C)^2 BN Fisher (ablation)
  invert_<n>.hlo.txt           damped Newton-Schulz inverse (Pallas)
  precond_<m>x<n>.hlo.txt      G^-1 grad A^-1 (Pallas)
  manifest.json                everything rust needs to wire it together
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as C
from . import model as M
from .kernels import (
    bn_full_fisher,
    bn_unit_fisher_inv,
    im2col,
    newton_schulz_inverse,
    precondition,
    syrk,
)

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def bucket(n: int) -> int:
    """Inversion executables are shared across factor dims by padding to
    a multiple of 16 (block-diagonal padding is exact; rust slices back)."""
    return ((n + 15) // 16) * 16


NS_ITERS = 20


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.exes = {}  # name -> {file, inputs, outputs}
        self.models = {}

    def emit(self, name, fn, in_specs):
        """Lower fn at in_specs and write <name>.hlo.txt (dedup by name)."""
        if name in self.exes:
            return name
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        self.exes[name] = {
            "file": fname,
            "inputs": [list(s.shape) for s in jax.tree_util.tree_leaves(in_specs)],
            "outputs": [list(o.shape) for o in jax.tree_util.tree_leaves(out_avals)],
        }
        print(f"  wrote {fname} ({len(text)} chars)")
        return name

    # -- shared executables -------------------------------------------

    def factor_conv_a(self, sig, batch):
        cin, h, w, k, s, p = sig
        name = f"factor_conv_a_c{cin}h{h}w{w}k{k}s{s}p{p}_b{batch}"
        ho = (h + 2 * p - k) // s + 1
        wo = (w + 2 * p - k) // s + 1
        scale = 1.0 / (batch * ho * wo)

        def fn(a_tap):
            patches = im2col(a_tap, k, s, p).reshape(-1, cin * k * k)
            return (syrk(patches, scale),)

        return self.emit(name, fn, (spec((batch, cin, h, w)),))

    def factor_g(self, rows, cols, scale_rows):
        """syrk over a (rows, cols) tap with scale 1/scale_rows. Used for
        conv G (rows=B*ho*wo, scale=B), fc A and fc G (rows=B, scale=B)."""
        name = f"factor_g_r{rows}c{cols}s{scale_rows}"

        def fn(tap2d):
            return (syrk(tap2d, 1.0 / scale_rows),)

        return self.emit(name, fn, (spec((rows, cols)),))

    def bn_inv(self, c):
        name = f"bn_inv_{c}"

        def fn(gg, gb, damping):
            return (bn_unit_fisher_inv(gg, gb, damping),)

        return self.emit(
            name, fn, (spec((self.batch, c)), spec((self.batch, c)), spec(()))
        )

    def bn_full(self, c):
        name = f"bn_full_{c}"

        def fn(gg, gb):
            return (bn_full_fisher(gg, gb),)

        return self.emit(name, fn, (spec((self.batch, c)), spec((self.batch, c))))

    def invert(self, n):
        nb = bucket(n)
        name = f"invert_{nb}"

        def fn(m, damping):
            return (newton_schulz_inverse(m, damping, iters=NS_ITERS),)

        self.emit(name, fn, (spec((nb, nb)), spec(())))
        return name

    def precond(self, m, n):
        name = f"precond_{m}x{n}"

        def fn(ginv, grad, ainv):
            return (precondition(ginv, grad, ainv),)

        return self.emit(
            name, fn, (spec((m, m)), spec((m, n)), spec((n, n)))
        )

    # -- per-model ------------------------------------------------------

    def add_model(self, cfg: C.ModelCfg):
        print(f"model {cfg.name}: batch={cfg.batch} in={cfg.in_shape}")
        self.batch = cfg.batch
        geo = M.layer_geometry(cfg)
        klayers = M.kfac_layers(cfg)
        pshapes = M.param_shapes(cfg)
        b = cfg.batch
        cc, hh, ww = cfg.in_shape
        k_classes = cfg.num_classes

        # ---- step executables
        params_specs = tuple(spec(s) for _, s in pshapes)
        x_spec = spec((b, cc, hh, ww))
        t_spec = spec((b, k_classes))
        step_emp = self.emit(
            f"step_{cfg.name}_emp",
            M.make_step(cfg, "emp"),
            (params_specs, x_spec, t_spec),
        )
        step_1mc = self.emit(
            f"step_{cfg.name}_1mc",
            M.make_step(cfg, "1mc"),
            (params_specs, x_spec, t_spec, spec((), jnp.uint32)),
        )
        bn_names = [n for n, kk, _ in klayers if kk == "bn"]
        bn_cs = [geo[n]["c"] for n in bn_names]
        eval_exe = self.emit(
            f"eval_{cfg.name}",
            M.make_eval(cfg),
            (
                params_specs,
                x_spec,
                t_spec,
                tuple(spec((c,)) for c in bn_cs),
                tuple(spec((c,)) for c in bn_cs),
            ),
        )

        # ---- init params
        params = M.init_params(cfg, seed=0)
        init_file = f"init_{cfg.name}.bin"
        with open(os.path.join(self.out_dir, init_file), "wb") as f:
            for p in params:
                f.write(np.asarray(p, dtype="<f4").tobytes())

        # ---- per-layer shared executables + layer table
        layer_entries = []
        for name, kind, op in klayers:
            g = geo[name]
            if kind == "bn":
                c = g["c"]
                layer_entries.append(
                    {
                        "name": name,
                        "kind": "bn",
                        "channels": c,
                        "bn_inv": self.bn_inv(c),
                        "bn_full": self.bn_full(c),
                        "invert_full": self.invert(2 * c),
                        "full_bucket": bucket(2 * c),
                        "gamma_param": name + ".gamma",
                        "beta_param": name + ".beta",
                    }
                )
                continue
            a_dim, g_dim = g["a_dim"], g["g_dim"]
            gm, gn = g["grad_shape"]
            if kind == "conv":
                factor_a = self.factor_conv_a(g["conv_sig"], b)
                rows = b * g["spatial"]
                factor_g = self.factor_g(rows, g_dim, b)
            else:
                factor_a = self.factor_g(b, a_dim, b)
                factor_g = self.factor_g(b, g_dim, b)
            layer_entries.append(
                {
                    "name": name,
                    "kind": kind,
                    "a_dim": a_dim,
                    "g_dim": g_dim,
                    "a_bucket": bucket(a_dim),
                    "g_bucket": bucket(g_dim),
                    "grad_shape": [gm, gn],
                    "a_tap_shape": list(g["a_tap"]),
                    "g_tap_shape": list(g["g_tap"]),
                    "factor_a": factor_a,
                    "factor_g": factor_g,
                    "invert_a": self.invert(a_dim),
                    "invert_g": self.invert(g_dim),
                    "precond": self.precond(gm, gn),
                    "weight_param": name + ".w",
                }
            )

        # ---- step output layout (mirrors model.make_step ordering)
        outputs = [
            {"name": "loss", "role": "loss", "shape": []},
            {"name": "ncorrect", "role": "ncorrect", "shape": []},
        ]
        for pname, shape in pshapes:
            outputs.append(
                {"name": f"grad:{pname}", "role": "grad", "param": pname,
                 "shape": list(shape)}
            )
        for name, kind, _ in klayers:
            if kind == "bn":
                continue
            outputs.append(
                {"name": f"a_tap:{name}", "role": "a_tap", "layer": name,
                 "shape": list(geo[name]["a_tap"])}
            )
            outputs.append(
                {"name": f"g_tap:{name}", "role": "g_tap", "layer": name,
                 "shape": list(geo[name]["g_tap"])}
            )
        for name in bn_names:
            outputs.append(
                {"name": f"g_gamma:{name}", "role": "g_gamma", "layer": name,
                 "shape": [b, geo[name]["c"]]}
            )
            outputs.append(
                {"name": f"g_beta:{name}", "role": "g_beta", "layer": name,
                 "shape": [b, geo[name]["c"]]}
            )
        for name in bn_names:
            outputs.append(
                {"name": f"bn_mean:{name}", "role": "bn_mean", "layer": name,
                 "shape": [geo[name]["c"]]}
            )
            outputs.append(
                {"name": f"bn_var:{name}", "role": "bn_var", "layer": name,
                 "shape": [geo[name]["c"]]}
            )

        self.models[cfg.name] = {
            "input_shape": [b, cc, hh, ww],
            "num_classes": k_classes,
            "batch": b,
            "params": [
                {"name": n, "shape": list(s)} for n, s in pshapes
            ],
            "init_file": init_file,
            "kfac_layers": layer_entries,
            "bn_order": bn_names,
            "step_outputs": outputs,
            "executables": {
                "step_emp": step_emp,
                "step_1mc": step_1mc,
                "eval": eval_exe,
            },
        }

    def write_manifest(self):
        manifest = {
            "version": 1,
            "ns_iters": NS_ITERS,
            "models": self.models,
            "executables": self.exes,
        }
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
        print(f"  wrote manifest.json ({len(self.exes)} executables)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models", default="mlp,convnet_small",
        help="comma-separated model config names",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    b = Builder(args.out_dir)
    for mname in args.models.split(","):
        b.add_model(C.MODELS[mname.strip()]())
    b.write_manifest()


if __name__ == "__main__":
    main()
