"""Pure-jnp oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here;
pytest (python/tests/) asserts allclose between kernel and oracle across a
hypothesis-driven sweep of shapes. The oracles are also what the L2 model
uses when SPNGD_USE_PALLAS=0 (debug escape hatch; artifacts default to the
Pallas path).
"""

import jax.numpy as jnp
from jax import lax


def syrk(x, scale=1.0):
    """scale * X^T X for X of shape (rows, cols) -> (cols, cols).

    This is the Kronecker-factor construction primitive:
      FC   A      = syrk(a,  1/B)        a: (B, d_in)
      FC   G      = syrk(gs, 1/B)        gs: (B, d_out), per-sample grads
      Conv A      = syrk(patches, 1/(B*h*w))   patches: (B*h*w, cin*k^2)
      Conv G      = syrk(gs2d, 1/B)      gs2d: (B*h*w, c_out)
    """
    x = x.astype(jnp.float32)
    return scale * (x.T @ x)


def matmul(a, b):
    """Plain A @ B in f32."""
    return a.astype(jnp.float32) @ b.astype(jnp.float32)


def newton_schulz_step(m, x):
    """One Newton-Schulz iteration: X <- X (2I - M X)."""
    n = m.shape[0]
    return x @ (2.0 * jnp.eye(n, dtype=jnp.float32) - m @ x)


def newton_schulz_inverse(m, damping, iters=20, power_iters=8):
    """Damped SPD inverse (M + damping*I)^-1 via Newton-Schulz.

    Init X0 = I/sigma with sigma a power-iteration estimate of the largest
    eigenvalue (padded by 10% + damping), which guarantees convergence for
    SPD inputs. Matmul-only: this is the MXU-friendly inversion the paper's
    Stage 4 performs with LU on V100 (see DESIGN.md section
    Hardware-Adaptation).
    """
    n = m.shape[0]
    md = m.astype(jnp.float32) + damping * jnp.eye(n, dtype=jnp.float32)

    v0 = jnp.full((n,), 1.0 / jnp.sqrt(n), dtype=jnp.float32)

    def pow_body(_, v):
        w = md @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = lax.fori_loop(0, power_iters, pow_body, v0)
    sigma = jnp.maximum(jnp.linalg.norm(md @ v), 1e-30) * 1.1 + damping

    x0 = jnp.eye(n, dtype=jnp.float32) / sigma

    def ns_body(_, x):
        return newton_schulz_step(md, x)

    return lax.fori_loop(0, iters, ns_body, x0)


def precondition(g_inv, grad, a_inv):
    """K-FAC preconditioned gradient: G^-1 @ grad @ A^-1 (Eq. 6/12)."""
    return (
        g_inv.astype(jnp.float32)
        @ grad.astype(jnp.float32)
        @ a_inv.astype(jnp.float32)
    )


def im2col(x, k, stride, pad):
    """Extract conv patches: (B, C, H, W) -> (B, ho*wo, C*k*k).

    Column order matches lax.conv_general_dilated_patches: feature dim is
    C-major then (kh, kw), i.e. index = c*k*k + kh*k + kw.
    """
    patches = lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(k, k),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (B, C*k*k, ho, wo)
    b, ckk, ho_wo = patches.shape[0], patches.shape[1], patches.shape[2] * patches.shape[3]
    return patches.reshape(b, ckk, ho_wo).transpose(0, 2, 1)


def bn_unit_fisher(g_gamma, g_beta, scale=None):
    """Unit-wise BatchNorm Fisher (Eq. 15-16): per-channel 2x2 blocks.

    g_gamma, g_beta: (B, C) per-sample gradients of log p w.r.t. gamma/beta.
    Returns (C, 2, 2) with block [[E[gg^2], E[gg gb]], [E[gb gg], E[gb^2]]].
    """
    b = g_gamma.shape[0]
    if scale is None:
        scale = 1.0 / b
    f11 = scale * jnp.sum(g_gamma * g_gamma, axis=0)
    f12 = scale * jnp.sum(g_gamma * g_beta, axis=0)
    f22 = scale * jnp.sum(g_beta * g_beta, axis=0)
    return jnp.stack(
        [jnp.stack([f11, f12], axis=-1), jnp.stack([f12, f22], axis=-1)], axis=-2
    )


def bn_unit_fisher_inv(g_gamma, g_beta, damping):
    """Damped closed-form inverse of the unit-wise BN Fisher (Eq. 17).

    Returns (C, 2, 2) inverse blocks of (F_c + damping*I).
    """
    f = bn_unit_fisher(g_gamma, g_beta)
    a = f[:, 0, 0] + damping
    bb = f[:, 0, 1]
    c = f[:, 1, 0]
    d = f[:, 1, 1] + damping
    det = a * d - bb * c
    inv = jnp.stack(
        [
            jnp.stack([d, -bb], axis=-1),
            jnp.stack([-c, a], axis=-1),
        ],
        axis=-2,
    )
    return inv / det[:, None, None]


def bn_full_fisher(g_gamma, g_beta, scale=None):
    """Full (2C x 2C) BatchNorm Fisher for the fullBN ablation (Sec. 4.2).

    Parameter order matches Eq. 14: (gamma_1, beta_1, ..., gamma_C, beta_C).
    """
    b, c = g_gamma.shape
    if scale is None:
        scale = 1.0 / b
    g = jnp.stack([g_gamma, g_beta], axis=-1).reshape(b, 2 * c)
    return scale * (g.T @ g)
