"""K-FAC preconditioning kernel: U = G^-1 @ gradW @ A^-1 (Eq. 6 + 12).

Two chained MXU-tiled Pallas matmuls; the (d_out, d_in) intermediate stays
in f32. This is the per-layer Stage-4 update math that the owning process
applies in the paper's model-parallel phase.
"""

import functools

import jax

from .matmul import matmul


@functools.partial(jax.jit, static_argnames=("interpret",))
def precondition(g_inv, grad, a_inv, interpret=True):
    """g_inv: (m, m), grad: (m, n), a_inv: (n, n) -> (m, n)."""
    t = matmul(g_inv, grad, interpret=interpret)
    return matmul(t, a_inv, interpret=interpret)
