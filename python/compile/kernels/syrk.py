"""Pallas SYRK kernel: O = scale * X^T X — Kronecker-factor construction.

The paper's hottest statistics kernel (Sec. 5.2 "construction of the
statistics"): for every Conv/FC layer, A and G are Gram matrices of
activations / per-sample output gradients. On V100 the authors used
Tensor-Core GEMMs; here the kernel is an MXU-tiled X^T X with the reduction
over the (large) row/batch axis streamed through VMEM.

Symmetry: only upper-triangular output blocks are computed (j >= i); the
strictly-lower blocks are filled by a transpose at the jnp level. This
halves MXU work for the factor construction, mirroring the paper's
symmetry-aware optimizations.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiles import block_for, block_rows, padded, padded_rows


def _syrk_kernel(x1_ref, x2_ref, o_ref):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(j >= i)
    def _acc():
        o_ref[...] += jnp.dot(
            x1_ref[...].T, x2_ref[...], preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def syrk(x, scale=1.0, interpret=True):
    """scale * X^T X for X (rows, cols) -> (cols, cols) symmetric."""
    r, c = x.shape
    pr, pc = padded_rows(r), padded(c)
    br, bc = block_rows(r), block_for(c)
    xp = x.astype(jnp.float32)
    if (pr, pc) != (r, c):
        xp = jnp.pad(xp, ((0, pr - r), (0, pc - c)))
    grid = (pc // bc, pc // bc, pr // br)
    upper = pl.pallas_call(
        _syrk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((br, bc), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bc, bc), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pc, pc), jnp.float32),
        interpret=interpret,
    )(xp, xp)
    upper = upper[:c, :c]
    # mirror: strict upper -> lower; diagonal blocks already full on both
    # triangles? No: diagonal *blocks* are fully computed (j == i passes),
    # but blocks strictly below are zero. Reconstruct symmetric result from
    # the block-upper part: O = U + U^T - diag_blocks overlap is handled by
    # taking the elementwise max-magnitude union via triangular masks.
    iu = jnp.triu(jnp.ones((c, c), dtype=bool))
    full = jnp.where(iu, upper, upper.T)
    return scale * full
