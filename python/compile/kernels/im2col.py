"""Pallas im2col kernel: conv-patch extraction (Eq. 10's M_A construction).

Grid is over the batch: each program loads one image (C, H, W) into VMEM,
extracts all k*k strided windows with static slices, and writes the
(ho*wo, C*k*k) patch matrix. Column order matches
lax.conv_general_dilated_patches (c-major, then kh, kw) so the oracle in
ref.py compares elementwise.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _im2col_kernel(x_ref, o_ref, *, k, stride, pad, ho, wo):
    x = x_ref[0]  # (C, H, W)
    c = x.shape[0]
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    cols = []
    for kh in range(k):
        for kw in range(k):
            win = jax.lax.slice(
                xp,
                (0, kh, kw),
                (c, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1),
                (1, stride, stride),
            )  # (C, ho, wo)
            cols.append(win)
    # (C, k*k, ho, wo) -> (C*k*k, ho*wo) -> (ho*wo, C*k*k)
    patches = jnp.stack(cols, axis=1).reshape(c * k * k, ho * wo)
    o_ref[0] = patches.T


@functools.partial(
    jax.jit, static_argnames=("k", "stride", "pad", "interpret")
)
def im2col(x, k, stride, pad, interpret=True):
    """(B, C, H, W) -> (B, ho*wo, C*k*k) conv patches."""
    b, c, h, w = x.shape
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    kern = functools.partial(
        _im2col_kernel, k=k, stride=stride, pad=pad, ho=ho, wo=wo
    )
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, c, h, w), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, ho * wo, c * k * k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, ho * wo, c * k * k), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32))
