"""Tiled Pallas matmul kernels.

Two entry points:
  matmul(a, b)                 -- O = A @ B
  matmul_2c_minus(a, b, c)     -- O = 2*C - A @ B   (the Newton-Schulz
                                   epilogue: X(2I - MX) = 2X - X(MX))

Both pad operands to tile multiples (zero padding is exact for matmul),
run an (i, j, k)-grid accumulation kernel, and slice the result back.

BlockSpec expresses the HBM->VMEM schedule: block (i, k) of A and (k, j)
of B stream through VMEM while the (i, j) output block stays resident
across the k axis -- the standard MXU-systolic schedule (the paper's
Tensor-Core GEMMs, re-thought for TPU; DESIGN.md Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiles import block_for, padded


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _mm_epilogue_kernel(a_ref, b_ref, c_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = 2.0 * c_ref[...]

    o_ref[...] -= jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _pad2(x, pm, pn):
    m, n = x.shape
    if m == pm and n == pn:
        return x
    return jnp.pad(x, ((0, pm - m), (0, pn - n)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(a, b, interpret=True):
    """O = A @ B with MXU-tiled Pallas kernel. a: (m, k), b: (k, n)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul shape mismatch {a.shape} @ {b.shape}"
    pm, pk, pn = padded(m), padded(k), padded(n)
    bm, bk, bn = block_for(m), block_for(k), block_for(n)
    ap = _pad2(a.astype(jnp.float32), pm, pk)
    bp = _pad2(b.astype(jnp.float32), pk, pn)
    grid = (pm // bm, pn // bn, pk // bk)
    out = pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_2c_minus(a, b, c, interpret=True):
    """O = 2*C - A @ B (Newton-Schulz epilogue). All f32 2-D."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c.shape == (m, n)
    pm, pk, pn = padded(m), padded(k), padded(n)
    bm, bk, bn = block_for(m), block_for(k), block_for(n)
    ap = _pad2(a.astype(jnp.float32), pm, pk)
    bp = _pad2(b.astype(jnp.float32), pk, pn)
    cp = _pad2(c.astype(jnp.float32), pm, pn)
    grid = (pm // bm, pn // bn, pk // bk)
    out = pl.pallas_call(
        _mm_epilogue_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        interpret=interpret,
    )(ap, bp, cp)
    return out[:m, :n]
