"""L1 Pallas kernels: K-FAC factor construction, inversion, preconditioning.

All kernels lower with interpret=True (CPU PJRT execution); real-TPU
structure (MXU tiles, VMEM blocking) is expressed via BlockSpec and
documented in DESIGN.md section Hardware-Adaptation.
"""

from .bn import bn_full_fisher, bn_unit_fisher_inv
from .im2col import im2col
from .inverse import newton_schulz_inverse
from .matmul import matmul, matmul_2c_minus
from .precondition import precondition
from .syrk import syrk

__all__ = [
    "bn_full_fisher",
    "bn_unit_fisher_inv",
    "im2col",
    "newton_schulz_inverse",
    "matmul",
    "matmul_2c_minus",
    "precondition",
    "syrk",
]
