"""Damped SPD inverse via Newton-Schulz iteration, built on the Pallas
matmul kernels (matmul-only -> MXU systolic array does all the work).

X0   = I / sigma         sigma >= lambda_max(M + damping I) by power iteration
X    <- X (2I - M X)     == matmul_2c_minus(X, matmul(M, X), X)

The iteration count is fixed (static HLO); 20 iterations reach f32
tolerance for the damping levels the coordinator uses (lambda >= 1e-4 of
the factor trace), validated against the Gauss-Jordan oracle in tests.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .matmul import matmul, matmul_2c_minus


@functools.partial(
    jax.jit, static_argnames=("iters", "power_iters", "interpret")
)
def newton_schulz_inverse(m, damping, iters=20, power_iters=8, interpret=True):
    """(M + damping*I)^-1 for SPD M (n, n); damping is a scalar array."""
    n = m.shape[0]
    eye = jnp.eye(n, dtype=jnp.float32)
    md = m.astype(jnp.float32) + damping * eye

    v0 = jnp.full((n,), 1.0 / jnp.sqrt(n), dtype=jnp.float32)

    def pow_body(_, v):
        w = md @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = lax.fori_loop(0, power_iters, pow_body, v0)
    sigma = jnp.maximum(jnp.linalg.norm(md @ v), 1e-30) * 1.1 + damping

    x = eye / sigma
    # Python-level loop: each iteration is two pallas_calls; static unroll
    # keeps the HLO free of dynamic control flow around the kernels.
    for _ in range(iters):
        p = matmul(md, x, interpret=interpret)
        x = matmul_2c_minus(x, p, x, interpret=interpret)
    return x
