"""Unit-wise BatchNorm Fisher + closed-form damped inverse (Sec. 4.2).

Small per-channel reductions; implemented in jnp (the 2x2 blocks are far
below MXU granularity — the paper's point is precisely that unitBN removes
the big (2C)^2 matrix, so there is nothing left to tile).
"""

import jax
import jax.numpy as jnp

from . import ref


@jax.jit
def bn_unit_fisher_inv(g_gamma, g_beta, damping):
    """(B, C) per-sample gamma/beta grads -> (C, 2, 2) damped inverses."""
    return ref.bn_unit_fisher_inv(g_gamma, g_beta, damping)


@jax.jit
def bn_full_fisher(g_gamma, g_beta):
    """(B, C) grads -> (2C, 2C) full BN Fisher (fullBN ablation)."""
    return ref.bn_full_fisher(g_gamma, g_beta)
