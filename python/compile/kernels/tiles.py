"""Tile-size policy shared by the Pallas kernels.

Two regimes:
 - dims <= MAX_SINGLE use one block covering the whole (8-aligned) extent.
   On TPU these all fit VMEM comfortably (512^2 f32 = 1 MiB << 16 MiB);
   under interpret=True this also minimizes the per-grid-cell overhead of
   the lowered while-loop, which profiling showed dominating wall time
   (EXPERIMENTS.md §Perf, L1 iteration 1).
 - larger dims tile at the 128x128 MXU systolic-array shape.

The reduction (row/batch) axis streams in ROW_BLOCK_MAX chunks: a
(8192 x 144) f32 block is ~4.7 MiB of VMEM — double-bufferable on real
hardware, and few enough grid cells to keep interpret mode fast.
"""

MXU_TILE = 128
MAX_SINGLE = 512
ROW_BLOCK_MAX = 8192


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def block_for(dim: int) -> int:
    """Block size for an output/operand dimension."""
    if dim <= MAX_SINGLE:
        return round_up(max(dim, 1), 8)
    return MXU_TILE


def padded(dim: int) -> int:
    """Padded extent so the dimension divides evenly into blocks."""
    return round_up(max(dim, 1), block_for(dim))


def block_rows(dim: int) -> int:
    """Block size for the streamed reduction axis (rows of X in syrk)."""
    if dim <= ROW_BLOCK_MAX:
        return round_up(max(dim, 1), 8)
    return ROW_BLOCK_MAX


def padded_rows(dim: int) -> int:
    return round_up(max(dim, 1), block_rows(dim))


def vmem_bytes_matmul(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint estimate for one matmul grid cell (A, B, O blocks)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
