"""AOT pipeline tests: manifest consistency, HLO text validity, init
params, bucket policy, and output-layout agreement with the model."""

import json
import os

import numpy as np
import pytest

from compile import aot, config as C, model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    b = aot.Builder(out)
    b.add_model(C.convnet_tiny(batch=4))
    b.write_manifest()
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    return out, manifest


def test_manifest_references_existing_files(built):
    out, manifest = built
    for name, e in manifest["executables"].items():
        path = os.path.join(out, e["file"])
        assert os.path.exists(path), f"{name} missing artifact file"
        head = open(path).read(200)
        assert "HloModule" in head, f"{name} is not HLO text"


def test_layer_table_consistent(built):
    _, manifest = built
    m = manifest["models"]["convnet_tiny"]
    exes = manifest["executables"]
    for l in m["kfac_layers"]:
        if l["kind"] == "bn":
            assert l["bn_inv"] in exes
            assert l["bn_full"] in exes
            assert l["invert_full"] in exes
            assert l["full_bucket"] >= 2 * l["channels"]
        else:
            assert l["factor_a"] in exes
            assert l["factor_g"] in exes
            assert l["invert_a"] in exes
            assert l["precond"] in exes
            # bucket = ceil16 >= dim
            assert l["a_bucket"] >= l["a_dim"]
            assert l["a_bucket"] % 16 == 0
            assert l["grad_shape"] == [l["g_dim"], l["a_dim"]]


def test_step_outputs_cover_model(built):
    _, manifest = built
    m = manifest["models"]["convnet_tiny"]
    cfg = C.convnet_tiny(batch=4)
    roles = [o["role"] for o in m["step_outputs"]]
    assert roles[0] == "loss" and roles[1] == "ncorrect"
    assert roles.count("grad") == len(M.param_shapes(cfg))
    n_convfc = sum(1 for _, k, _ in M.kfac_layers(cfg) if k != "bn")
    n_bn = sum(1 for _, k, _ in M.kfac_layers(cfg) if k == "bn")
    assert roles.count("a_tap") == n_convfc
    assert roles.count("g_tap") == n_convfc
    assert roles.count("g_gamma") == n_bn
    assert roles.count("bn_mean") == n_bn


def test_init_params_file_size(built):
    out, manifest = built
    m = manifest["models"]["convnet_tiny"]
    total = sum(int(np.prod(p["shape"])) for p in m["params"])
    size = os.path.getsize(os.path.join(out, m["init_file"]))
    assert size == 4 * total


def test_bucket_function():
    assert aot.bucket(1) == 16
    assert aot.bucket(16) == 16
    assert aot.bucket(17) == 32
    assert aot.bucket(288) == 288


def test_executables_deduplicated(built):
    out, manifest = built
    files = [e["file"] for e in manifest["executables"].values()]
    assert len(files) == len(set(files)), "duplicate artifact files"
