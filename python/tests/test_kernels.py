"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes; fixed-seed numpy draws the values. These tests
are the CORE correctness signal for everything the rust coordinator
executes via the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bn_full_fisher,
    bn_unit_fisher_inv,
    im2col,
    matmul,
    matmul_2c_minus,
    newton_schulz_inverse,
    precondition,
    syrk,
)
from compile.kernels import ref

RNG = np.random.default_rng(0)


def randm(*shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


def spd(n, damp=0.1):
    b = randm(n, n)
    return (b @ b.T / n + damp * np.eye(n)).astype(np.float32)


# ---------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
)
def test_matmul_matches_ref(m, k, n):
    a, b = randm(m, k), randm(k, n)
    got = np.asarray(matmul(a, b))
    want = np.asarray(ref.matmul(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_tile_boundaries():
    # exactly at/around the 128 MXU tile edge
    for m, k, n in [(128, 128, 128), (129, 127, 128), (256, 1, 7)]:
        a, b = randm(m, k), randm(k, n)
        np.testing.assert_allclose(
            np.asarray(matmul(a, b)), a @ b, rtol=1e-4, atol=1e-4
        )


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96))
def test_matmul_epilogue(m, k, n):
    a, b, c = randm(m, k), randm(k, n), randm(m, n)
    got = np.asarray(matmul_2c_minus(a, b, c))
    np.testing.assert_allclose(got, 2 * c - a @ b, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ syrk

@settings(max_examples=25, deadline=None)
@given(r=st.integers(1, 300), c=st.integers(1, 160))
def test_syrk_matches_ref(r, c):
    x = randm(r, c)
    scale = 1.0 / r
    got = np.asarray(syrk(x, scale))
    want = np.asarray(ref.syrk(x, scale))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_syrk_symmetric_output():
    x = randm(64, 48)
    a = np.asarray(syrk(x, 1.0 / 64))
    np.testing.assert_allclose(a, a.T, rtol=0, atol=1e-6)


def test_syrk_psd():
    x = randm(100, 30)
    a = np.asarray(syrk(x, 1.0 / 100)).astype(np.float64)
    eigs = np.linalg.eigvalsh((a + a.T) / 2)
    assert eigs.min() > -1e-5


# ---------------------------------------------------------------- im2col

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 4),
    c=st.integers(1, 8),
    hw=st.integers(4, 20),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
)
def test_im2col_matches_ref(b, c, hw, k, stride):
    pad = k // 2
    x = randm(b, c, hw, hw)
    got = np.asarray(im2col(x, k, stride, pad))
    want = np.asarray(ref.im2col(x, k, stride, pad))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_im2col_identity_k1():
    x = randm(2, 3, 5, 5)
    got = np.asarray(im2col(x, 1, 1, 0))  # (B, 25, 3)
    want = x.reshape(2, 3, 25).transpose(0, 2, 1)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_conv_factor_pipeline_matches_direct_gram():
    """A-factor for a conv layer: im2col -> syrk == direct patch Gram."""
    b, c, h, k = 2, 4, 8, 3
    x = randm(b, c, h, h)
    patches = np.asarray(ref.im2col(x, k, 1, 1))  # (B, hw, c*k*k)
    flat = patches.reshape(-1, c * k * k)
    scale = 1.0 / flat.shape[0]
    want = scale * flat.T @ flat
    got_patches = np.asarray(im2col(x, k, 1, 1)).reshape(-1, c * k * k)
    got = np.asarray(syrk(got_patches, scale))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- newton-schulz

@settings(max_examples=12, deadline=None)
@given(n=st.integers(2, 96))
def test_ns_inverse_matches_numpy(n):
    m = spd(n, damp=0.05)
    lam = 0.05
    got = np.asarray(newton_schulz_inverse(m, jnp.float32(lam), iters=25))
    want = np.linalg.inv(m.astype(np.float64) + lam * np.eye(n))
    resid = np.abs(got - want).max() / max(1.0, np.abs(want).max())
    assert resid < 5e-3, f"n={n} resid={resid}"


def test_ns_inverse_matches_ref_oracle():
    m = spd(32)
    lam = 0.1
    got = np.asarray(newton_schulz_inverse(m, jnp.float32(lam), iters=20))
    want = np.asarray(ref.newton_schulz_inverse(m, lam, iters=20))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_ns_inverse_identity():
    eye = np.eye(16, dtype=np.float32)
    got = np.asarray(newton_schulz_inverse(eye, jnp.float32(0.0), iters=20))
    np.testing.assert_allclose(got, eye, rtol=1e-4, atol=1e-4)


def test_ns_residual_shrinks_with_iters():
    m = spd(48, damp=0.02)
    lam = 0.02
    md = m.astype(np.float64) + lam * np.eye(48)
    r = []
    for it in [5, 12, 25]:
        x = np.asarray(newton_schulz_inverse(m, jnp.float32(lam), iters=it))
        r.append(np.abs(md @ x - np.eye(48)).max())
    assert r[2] < r[0], f"residuals {r}"
    assert r[2] < 1e-2


# --------------------------------------------------------- precondition

@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 80), n=st.integers(1, 80))
def test_precondition_matches_ref(m, n):
    ginv, grad, ainv = randm(m, m), randm(m, n), randm(n, n)
    got = np.asarray(precondition(ginv, grad, ainv))
    want = np.asarray(ref.precondition(ginv, grad, ainv))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_precondition_with_identity_is_noop():
    grad = randm(24, 36)
    got = np.asarray(precondition(np.eye(24, dtype=np.float32), grad,
                                  np.eye(36, dtype=np.float32)))
    np.testing.assert_allclose(got, grad, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- BN

@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 64), c=st.integers(1, 64))
def test_bn_unit_fisher_inverse(b, c):
    gg, gb = randm(b, c), randm(b, c)
    lam = 0.05
    inv = np.asarray(bn_unit_fisher_inv(gg, gb, jnp.float32(lam)))
    f = np.asarray(ref.bn_unit_fisher(gg, gb))
    for ch in range(c):
        blk = f[ch] + lam * np.eye(2)
        np.testing.assert_allclose(
            inv[ch] @ blk, np.eye(2), rtol=1e-3, atol=1e-3
        )


def test_bn_full_fisher_contains_unit_blocks():
    """The 2x2 diagonal blocks of the full BN Fisher equal the unit-wise
    Fisher — the structural claim behind the unitBN approximation."""
    b, c = 32, 8
    gg, gb = randm(b, c), randm(b, c)
    full = np.asarray(bn_full_fisher(gg, gb))
    unit = np.asarray(ref.bn_unit_fisher(gg, gb))
    assert full.shape == (2 * c, 2 * c)
    for ch in range(c):
        np.testing.assert_allclose(
            full[2 * ch: 2 * ch + 2, 2 * ch: 2 * ch + 2],
            unit[ch],
            rtol=1e-5,
            atol=1e-5,
        )
    np.testing.assert_allclose(full, full.T, atol=1e-6)
