"""L2 model correctness: probe-trick Fisher taps vs direct per-sample
gradients (vmap), factor assembly vs definitions (Eqs. 9, 11, 15-16),
shape bookkeeping, and eval-mode BN behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import config as C, model as M
from compile.kernels import ref


def data(cfg, seed=0):
    rng = np.random.default_rng(seed)
    b = cfg.batch
    c, h, w = cfg.in_shape
    x = rng.standard_normal((b, c, h, w)).astype(np.float32)
    t = np.eye(cfg.num_classes, dtype=np.float32)[
        rng.integers(0, cfg.num_classes, b)
    ]
    return x, t


def run_step(cfg, fisher="emp", seed=0):
    params = M.init_params(cfg, 3)
    x, t = data(cfg, seed)
    step = M.make_step(cfg, fisher)
    if fisher == "1mc":
        outs = step(params, x, t, jnp.uint32(11))
    else:
        outs = step(params, x, t)
    return params, x, t, outs


def split_outputs(cfg, outs):
    """Mirror of the manifest output layout."""
    klayers = M.kfac_layers(cfg)
    nparams = len(M.param_shapes(cfg))
    loss, ncorrect = outs[0], outs[1]
    grads = outs[2 : 2 + nparams]
    i = 2 + nparams
    taps = {}
    for name, kind, _ in klayers:
        if kind == "bn":
            continue
        taps[name] = (outs[i], outs[i + 1])
        i += 2
    bn_taps = {}
    for name, kind, _ in klayers:
        if kind != "bn":
            continue
        bn_taps[name] = (outs[i], outs[i + 1])
        i += 2
    bn_stats = {}
    for name, kind, _ in klayers:
        if kind != "bn":
            continue
        bn_stats[name] = (outs[i], outs[i + 1])
        i += 2
    assert i == len(outs)
    return loss, ncorrect, grads, taps, bn_taps, bn_stats


def per_sample_probe_grads(cfg, params, x, t):
    """Direct per-sample gradients w.r.t. every probe via vmap — the
    oracle for the probe trick."""
    geo = M.layer_geometry(cfg)
    bn_shapes = M._bn_probe_shapes(cfg, geo)
    klayers = M.kfac_layers(cfg)

    def one(xi, ti):
        xi = xi[None]
        ti = ti[None]
        probes = {}
        for name, kind, _ in klayers:
            shape = bn_shapes[name] if kind == "bn" else geo[name]["g_tap"]
            probes[name] = jnp.zeros((1,) + tuple(shape[1:]), jnp.float32)

        def f(probes):
            pdict = M.params_to_dict(cfg, params)
            logits, _, _ = M.forward(cfg, pdict, probes, xi)
            logp = jax.nn.log_softmax(logits)
            return -jnp.sum(ti * logp)

        return jax.grad(f)(probes)

    return jax.vmap(one)(jnp.asarray(x), jnp.asarray(t))


@pytest.fixture(scope="module")
def tiny():
    return C.convnet_tiny(batch=4)


def test_probe_grads_match_per_sample(tiny):
    """g_tap (B-scaled probe grad) == per-sample dloss_i/ds — BN stats in
    the vmap oracle differ (per-sample batch of 1), so compare on the MLP
    where no BN exists, elementwise."""
    cfg = C.mlp(batch=6)
    params, x, t, outs = run_step(cfg)
    _, _, _, taps, _, _ = split_outputs(cfg, outs)
    ps = per_sample_probe_grads(cfg, params, x, t)
    for name, kind, _ in M.kfac_layers(cfg):
        if kind != "fc":
            continue
        gs = np.asarray(taps[name][1])  # (B, dout)
        want = np.asarray(ps[name]).reshape(gs.shape)
        np.testing.assert_allclose(gs, want, rtol=1e-4, atol=1e-5)


def test_fc_factor_assembly_matches_kfac_definition():
    """A = E[a a^T], G = E[g g^T] assembled from taps equals the K-FAC
    definition computed from explicit per-sample grads (Eq. 9)."""
    cfg = C.mlp(batch=8)
    params, x, t, outs = run_step(cfg)
    _, _, _, taps, _, _ = split_outputs(cfg, outs)
    ps = per_sample_probe_grads(cfg, params, x, t)
    b = cfg.batch
    for name, kind, _ in M.kfac_layers(cfg):
        a_tap, g_tap = taps[name]
        A = np.asarray(ref.syrk(a_tap, 1.0 / b))
        G = np.asarray(ref.syrk(g_tap, 1.0 / b))
        gs = np.asarray(ps[name]).reshape(b, -1)
        G_want = gs.T @ gs / b
        np.testing.assert_allclose(G, G_want, rtol=1e-4, atol=1e-6)
        a = np.asarray(a_tap)
        np.testing.assert_allclose(A, a.T @ a / b, rtol=1e-4, atol=1e-6)


def test_fc_kron_grad_identity():
    """Sanity: mean gradient == E[g a^T] reconstructed from taps — ties
    the taps to the actual parameter gradient (loss sign included)."""
    cfg = C.mlp(batch=8)
    params, x, t, outs = run_step(cfg)
    _, _, grads, taps, _, _ = split_outputs(cfg, outs)
    pnames = [n for n, _ in M.param_shapes(cfg)]
    b = cfg.batch
    for name, kind, _ in M.kfac_layers(cfg):
        a_tap, g_tap = np.asarray(taps[name][0]), np.asarray(taps[name][1])
        # g_tap rows are B * dL_mean/ds_i = per-sample dCE_i/ds (positive CE)
        want = g_tap.T @ a_tap / b
        g = np.asarray(grads[pnames.index(name + ".w")])
        np.testing.assert_allclose(g, want, rtol=1e-3, atol=1e-5)


def test_conv_factor_shapes_and_psd(tiny):
    cfg = tiny
    params, x, t, outs = run_step(cfg)
    _, _, _, taps, _, _ = split_outputs(cfg, outs)
    geo = M.layer_geometry(cfg)
    for name, kind, _ in M.kfac_layers(cfg):
        if kind != "conv":
            continue
        g = geo[name]
        a_tap, g_tap = taps[name]
        assert tuple(a_tap.shape) == g["a_tap"]
        assert tuple(g_tap.shape) == g["g_tap"]
        cin, hh, ww, k, s, p = g["conv_sig"]
        patches = np.asarray(ref.im2col(a_tap, k, s, p)).reshape(-1, g["a_dim"])
        A = patches.T @ patches / patches.shape[0]
        eig = np.linalg.eigvalsh((A + A.T) / 2)
        assert eig.min() > -1e-5
        gs2 = np.asarray(g_tap).transpose(0, 2, 3, 1).reshape(-1, g["g_dim"])
        G = gs2.T @ gs2 / cfg.batch
        eig = np.linalg.eigvalsh((G + G.T) / 2)
        assert eig.min() > -1e-5


def test_bn_taps_match_param_grads(tiny):
    """mean over batch of per-sample BN grads == the parameter gradient
    (consistency of g_gamma/g_beta taps with autodiff)."""
    cfg = tiny
    params, x, t, outs = run_step(cfg)
    _, _, grads, _, bn_taps, _ = split_outputs(cfg, outs)
    pnames = [n for n, _ in M.param_shapes(cfg)]
    b = cfg.batch
    for name, kind, _ in M.kfac_layers(cfg):
        if kind != "bn":
            continue
        gg, gb = np.asarray(bn_taps[name][0]), np.asarray(bn_taps[name][1])
        gamma_grad = np.asarray(grads[pnames.index(name + ".gamma")])
        beta_grad = np.asarray(grads[pnames.index(name + ".beta")])
        np.testing.assert_allclose(gg.mean(0), gamma_grad, rtol=1e-3, atol=1e-5)
        np.testing.assert_allclose(gb.mean(0), beta_grad, rtol=1e-3, atol=1e-5)


def test_1mc_same_loss_different_taps(tiny):
    cfg = tiny
    params = M.init_params(cfg, 3)
    x, t = data(cfg)
    emp = M.make_step(cfg, "emp")(params, x, t)
    mc = M.make_step(cfg, "1mc")(params, x, t, jnp.uint32(11))
    assert float(emp[0]) == pytest.approx(float(mc[0]), rel=1e-6)
    # param grads identical (true labels); taps differ (sampled labels)
    nparams = len(M.param_shapes(cfg))
    for i in range(2, 2 + nparams):
        np.testing.assert_allclose(
            np.asarray(emp[i]), np.asarray(mc[i]), rtol=1e-5, atol=1e-6
        )
    _, _, _, taps_e, _, _ = split_outputs(cfg, emp)
    _, _, _, taps_m, _, _ = split_outputs(cfg, mc)
    diffs = [
        np.abs(np.asarray(taps_e[n][1]) - np.asarray(taps_m[n][1])).max()
        for n, k, _ in M.kfac_layers(cfg)
        if k != "bn"
    ]
    assert max(diffs) > 1e-6, "1mc taps should differ from emp taps"


def test_eval_uses_running_stats(tiny):
    cfg = tiny
    params = M.init_params(cfg, 3)
    x, t = data(cfg)
    ev = M.make_eval(cfg)
    bn_names = [n for n, k, _ in M.kfac_layers(cfg) if k == "bn"]
    geo = M.layer_geometry(cfg)
    m0 = [jnp.zeros((geo[n]["c"],)) for n in bn_names]
    v0 = [jnp.ones((geo[n]["c"],)) for n in bn_names]
    l0, _ = ev(params, x, t, m0, v0)
    v1 = [10.0 * v for v in v0]
    l1, _ = ev(params, x, t, m0, v1)
    assert float(l0) != pytest.approx(float(l1)), "bn stats must matter"


def test_param_order_deterministic(tiny):
    a = [n for n, _ in M.param_shapes(tiny)]
    b = [n for n, _ in M.param_shapes(C.convnet_tiny(batch=4))]
    assert a == b


def test_init_henormal_stats():
    cfg = C.mlp(batch=4)
    params = M.init_params(cfg, 0)
    shapes = M.param_shapes(cfg)
    for (name, shape), p in zip(shapes, params):
        if name.endswith(".w") and np.prod(shape) > 1000:
            fan_in = int(np.prod(shape[1:]))
            std = np.asarray(p).std()
            assert std == pytest.approx((2.0 / fan_in) ** 0.5, rel=0.2)
