"""Python port of dist::RingComm's round state machines, stress-tested
with real threads to validate the synchronization protocol (deadlock
freedom, round reuse, canonical reduction results) AND the wire-byte
accounting: every round charges the same per-GPU ring formula as
rust/src/collectives/comm.rs::ring_wire_bytes, and run_case asserts the
counters against closed-form expectations per step — for the f32 wire
(elem_bytes=4) and the mixed/f16 wire (elem_bytes=2), where gradient and
statistics bytes halve while parameters stay f32. CI runs this file as
the `python-protocol` job.

It also mirrors the *framed* multi-process wire protocol
(rust/src/collectives/wire.rs): header layout, FNV-1a payload checksum,
balanced segment splitting and the closed-form per-round byte counters,
pinned to the same vectors as the Rust unit tests so ProcComm's
`WireStats` accounting and this model cannot drift apart silently."""
import math, struct, threading, random, sys


def ring_wire_bytes(p, elem_bytes, elems):
    """Per-GPU wire bytes of an N-element ring collective — the exact
    mirror of comm.rs: round(elems * (p-1)/p * elem_bytes) with Rust's
    f64::round (half away from zero; Python's round() is half-to-even,
    which disagrees at e.g. p=4, elem_bytes=2, elems=3 -> 4.5)."""
    p = max(p, 1)
    x = elems * (p - 1) / p * elem_bytes
    return int(math.floor(x + 0.5))


class RingComm:
    def __init__(self, p, chunk=7, elem_bytes=4):
        self.p = max(p, 1)
        self.chunk = max(chunk, 1)
        self.elem_bytes = elem_bytes  # grad/stat wire width: 4=f32, 2=f16
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)
        # grad round state
        self.g = dict(active=False)
        # stat round
        self.s = dict(active=False)
        # gather round
        self.ga = dict(active=False)
        # per-GPU wire-byte counters, same split as CommStats
        self.rs_stats = 0
        self.ar_grads = 0
        self.ag_params = 0

    # ---- stat board
    def begin_stats(self, n_items, lanes, stat_len=3):
        if n_items == 0:
            return
        with self.cv:
            assert not self.s['active'], "stat round still open"
            self.s = dict(active=True, lanes=lanes, n_items=n_items,
                          stat_len=stat_len,
                          slots=[[None] * lanes for _ in range(n_items)],
                          posted=[0] * n_items, reduced=0)

    def publish_stat(self, item, lane, val):
        with self.cv:
            st = self.s
            assert st['active']
            assert st['slots'][item][lane] is None
            st['slots'][item][lane] = val
            st['posted'][item] += 1
            if st['posted'][item] == st['lanes']:
                self.cv.notify_all()

    def reduce_stat(self, item):
        with self.cv:
            st = self.s
            assert st['active']
            while st['posted'][item] < st['lanes']:
                self.cv.wait()
            taken = st['slots'][item]
            st['slots'][item] = []
        red = [sum(col) / len(taken) for col in zip(*taken)]
        with self.cv:
            st = self.s
            st['reduced'] += 1
            if st['reduced'] == st['n_items']:
                st['active'] = False
                # ReduceScatterV: one charge per round over the packed
                # payload (here: n_items stat vectors of 3 elements)
                self.rs_stats += ring_wire_bytes(
                    self.p, self.elem_bytes, st['n_items'] * st['stat_len'])
        return red

    # ---- grad AllReduce (post-by-move; one mean copy per rank drain)
    def grad_post(self, my_lanes, total):
        if not my_lanes:
            return
        n = len(my_lanes[0][1])
        with self.cv:
            while True:
                st = self.g
                if not st['active']:
                    nch = 0 if n == 0 else -(-n // self.chunk)
                    self.g = dict(active=True, n=n, total=total, posted=0,
                                  participants=0,
                                  lanes=[None] * total, frozen=None,
                                  reduced=[0.0] * n, next_chunk=0,
                                  done=0, nchunks=nch, drained=0)
                    st = self.g
                    break
                if st['posted'] < st['total']:
                    break
                self.cv.wait()
            assert st['total'] == total
            st['participants'] += 1
            for g_idx, buf in my_lanes:
                assert st['lanes'][g_idx] is None
                st['lanes'][g_idx] = buf  # moved, not copied
                st['posted'] += 1
            if st['posted'] == st['total']:
                self.cv.notify_all()

    def grad_finish(self):
        with self.cv:
            st = self.g
            assert st['active'], "finish without post"
            while st['posted'] < st['total']:
                self.cv.wait()
            if st['frozen'] is None:
                st['frozen'] = st['lanes']
                st['lanes'] = []
            frozen, n, total = st['frozen'], st['n'], st['total']
        while True:
            with self.cv:
                st = self.g
                if st['next_chunk'] >= st['nchunks']:
                    break
                c = st['next_chunk']
                st['next_chunk'] += 1
            s0 = c * self.chunk
            e0 = min(s0 + self.chunk, n)
            out = [sum(lane[i] for lane in frozen) / total
                   for i in range(s0, e0)]
            with self.cv:
                st = self.g
                st['reduced'][s0:e0] = out
                st['done'] += 1
                if st['done'] == st['nchunks']:
                    self.cv.notify_all()
        with self.cv:
            st = self.g
            while st['done'] < st['nchunks']:
                self.cv.wait()
            st['drained'] += 1
            if st['drained'] == st['participants']:
                out = st['reduced']
                st['active'] = False
                # AllReduce = ReduceScatter + AllGather: 2x the ring bytes
                self.ar_grads += 2 * ring_wire_bytes(self.p, self.elem_bytes, n)
                self.cv.notify_all()
                return out
            return list(st['reduced'])

    # ---- gather
    def all_gather_v(self, rank, segs, owner_of):
        with self.cv:
            while True:
                st = self.ga
                if not st['active']:
                    self.ga = dict(active=True, n_segs=len(segs), posted=0,
                                   segs=[None] * len(segs), joined=1, drained=0)
                    st = self.ga
                    break
                if st['joined'] < self.p:
                    st['joined'] += 1
                    break
                self.cv.wait()
            assert st['n_segs'] == len(segs)
            for i, seg in enumerate(segs):
                if owner_of[i] % self.p == rank:
                    assert st['segs'][i] is None
                    st['segs'][i] = list(seg)
                    st['posted'] += 1
            if st['posted'] == st['n_segs']:
                self.cv.notify_all()
            while st['posted'] < st['n_segs']:
                self.cv.wait()
            for i in range(len(segs)):
                segs[i] = list(st['segs'][i])
            st['drained'] += 1
            if st['drained'] == self.p:
                st['active'] = False
                # parameters always travel f32, whatever the grad wire is
                self.ag_params += ring_wire_bytes(
                    self.p, 4, sum(len(s) for s in segs))
                self.cv.notify_all()


def run_case(p, micro, n_items, n, steps, chunk, seed, elem_bytes=4):
    rng = random.Random(seed)
    ring = RingComm(p, chunk, elem_bytes)
    total = p * micro
    lane_data = [[rng.uniform(-1, 1) for _ in range(n)] for _ in range(total)]
    stat_data = [[rng.uniform(-1, 1) for _ in range(3)] for _ in range(total * n_items)]
    owners = [i % p for i in range(n_items)]
    results = {}
    errors = []
    lock = threading.Lock()

    def one_step(rank, step):
        try:
            my_lanes = [g for g in range(total) if g % p == rank]
            pubs = [(i, g) for g in my_lanes for i in range(n_items)]
            rng2 = random.Random(seed * 1000 + rank * 100 + step)
            rng2.shuffle(pubs)
            for i, g in pubs:
                ring.publish_stat(i, g, stat_data[g * n_items + i])
            lanes = [(g, list(lane_data[g])) for g in my_lanes]
            ring.grad_post(lanes, total)
            red = {}
            for i in range(n_items):
                if owners[i] == rank:
                    red[i] = ring.reduce_stat(i)
            mean = ring.grad_finish() if my_lanes else []
            segs = [[float(rank)] * (i + 1) if owners[i] % p == rank
                    else [0.0] * (i + 1) for i in range(n_items)]
            ring.all_gather_v(rank, segs, owners)
            with lock:
                for i, v in red.items():
                    results[(step, i)] = v
                results[(step, 'grad', rank)] = [list(mean)]
                results[(step, 'ag', rank)] = segs
        except Exception as e:  # noqa
            with lock:
                errors.append((rank, repr(e)))

    for step in range(steps):
        ring.begin_stats(n_items, total)
        ts = [threading.Thread(target=one_step, args=(r, step)) for r in range(p)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60)
            if t.is_alive():
                print(f"DEADLOCK p={p} micro={micro} chunk={chunk} step={step}")
                sys.exit(1)
        if errors:
            print("ERRORS:", errors)
            sys.exit(1)

    for step in range(steps):
        for i in range(n_items):
            want = [sum(stat_data[g * n_items + i][j] for g in range(total)) / total
                    for j in range(3)]
            assert results[(step, i)] == want, (step, i)
        want_grad = [sum(lane_data[g][j] for g in range(total)) / total for j in range(n)]
        for r in range(p):
            for b in results[(step, 'grad', r)]:
                assert b == want_grad, (step, r)
            segs = results[(step, 'ag', r)]
            for i in range(n_items):
                assert segs[i] == [float(owners[i])] * (i + 1), (step, r, i)

    # ---- byte accounting vs the closed-form ring formula (one grad
    # round, one stat round, one gather round per step)
    exp_ar = steps * 2 * ring_wire_bytes(p, elem_bytes, n)
    exp_rs = steps * ring_wire_bytes(p, elem_bytes, n_items * 3)
    seg_elems = sum(i + 1 for i in range(n_items))
    exp_ag = steps * ring_wire_bytes(p, 4, seg_elems)
    assert ring.ar_grads == exp_ar, (ring.ar_grads, exp_ar)
    assert ring.rs_stats == exp_rs, (ring.rs_stats, exp_rs)
    assert ring.ag_params == exp_ag, (ring.ag_params, exp_ag)
    print(f"OK p={p} micro={micro} items={n_items} n={n} chunk={chunk} "
          f"steps={steps} wire={elem_bytes}B "
          f"(ar={ring.ar_grads} rs={ring.rs_stats} ag={ring.ag_params})")
    return ring


# ---- framed multi-process wire (mirror of collectives/wire.rs) ----
WIRE_HEADER = 16  # magic(4) + version(2) + kind(1) + flags(1) + len(4) + fnv(4)


def fnv1a(data):
    """FNV-1a 32 over the payload — the frame checksum."""
    h = 0x811c9dc5
    for b in data:
        h = ((h ^ b) * 0x01000193) & 0xffffffff
    return h


def encode_frame(kind, flags, payload):
    return (b"SPWF" + struct.pack('<HBB', 1, kind, flags)
            + struct.pack('<II', len(payload), fnv1a(payload)) + payload)


def split_segments(elems, parts):
    """Balanced contiguous (start, len) split, empty segments dropped."""
    parts = max(parts, 1)
    base, rem = divmod(elems, parts)
    out, start = [], 0
    for i in range(parts):
        ln = base + (1 if i < rem else 0)
        if ln:
            out.append((start, ln))
            start += ln
    return out


def grad_round_tx_bytes(seg_lens, lanes, elem_bytes):
    """Framed bytes the coordinator sends for one gradient AllReduce:
    one ReduceGrad job per segment, payload [job][n_lanes][seg_len][pad]
    (16 bytes) + lanes * seg_len elements."""
    return sum(WIRE_HEADER + 16 + lanes * ln * elem_bytes for ln in seg_lens)


def grad_round_rx_bytes(seg_lens, elem_bytes):
    """One GradSeg reply per segment: [job][seg_len] (8) + elements."""
    return sum(WIRE_HEADER + 8 + ln * elem_bytes for ln in seg_lens)


def stat_item_tx_bytes(rows, cols, lanes, elem_bytes):
    """One ReduceStats job: [item][rows][cols][lanes] (16) + lane mats."""
    return WIRE_HEADER + 16 + lanes * rows * cols * elem_bytes


def stat_item_rx_bytes(rows, cols):
    """One StatResult reply — owner masters are always exact f32."""
    return WIRE_HEADER + 16 + rows * cols * 4


def check_proc_frame_bytes():
    """Pin the framed-wire model to the vectors asserted by the Rust
    unit tests (wire.rs::closed_form_byte_vectors_pinned and the frame
    round-trip tests)."""
    # checksum constants shared with wire.rs
    assert fnv1a(b"") == 0x811c9dc5
    assert fnv1a(b"SPWF") == 0x5ebb61ef
    # Hello(uid=42): kind 1, 8-byte payload -> a 24-byte frame with the
    # exact header prefix the Rust encoder emits
    hello = encode_frame(1, 0, struct.pack('<Q', 42))
    assert len(hello) == 24, len(hello)
    assert hello.startswith(b"SPWF\x01\x00\x01\x00"), hello
    # 10 elems over 3 workers -> balanced [4, 3, 3]
    assert split_segments(10, 3) == [(0, 4), (4, 3), (7, 3)]
    segs = [ln for _, ln in split_segments(10, 3)]
    # gradient round, 4 lanes: f32 wire then the real-f16 wire
    assert grad_round_tx_bytes(segs, 4, 4) == 256, grad_round_tx_bytes(segs, 4, 4)
    assert grad_round_rx_bytes(segs, 4) == 112
    assert grad_round_tx_bytes(segs, 4, 2) == 176
    assert grad_round_rx_bytes(segs, 2) == 92
    # one 8x8 statistic over 2 lanes; results always come back f32
    assert stat_item_tx_bytes(8, 8, 2, 4) == 544
    assert stat_item_tx_bytes(8, 8, 2, 2) == 288
    assert stat_item_rx_bytes(8, 8) == 288
    # f16 halves exactly the payload-element part of every data frame
    for ln, lanes in ((23, 2), (100, 6)):
        s = [l for _, l in split_segments(ln, 3)]
        f32b = grad_round_tx_bytes(s, lanes, 4)
        f16b = grad_round_tx_bytes(s, lanes, 2)
        assert (f32b - f16b) * 2 == f32b - len(s) * (WIRE_HEADER + 16), (ln, lanes)
    print("framed proc wire matches rust/src/collectives/wire.rs vectors")


def check_wire_formula():
    """Pin ring_wire_bytes to the vectors asserted by the Rust unit tests
    (collectives/comm.rs + tests/dist_collectives.rs) so the Python and
    Rust accounting cannot drift apart silently."""
    # p=4, AllReduce of 2 f32 elems: 2 * round(2 * 3/4 * 4) = 12
    assert 2 * ring_wire_bytes(4, 4, 2) == 12
    # p=2, packed 2x2 stat (3 elems), f32: round(3 * 1/2 * 4) = 6
    assert ring_wire_bytes(2, 4, 3) == 6
    # same payload on the f16 wire: exactly half
    assert ring_wire_bytes(2, 2, 3) == 3
    # Rust f64::round is half-away-from-zero: 3 * 3/4 * 2 = 4.5 -> 5
    # (Python's builtin round() would give 4 here)
    assert ring_wire_bytes(4, 2, 3) == 5
    # single worker moves nothing
    assert ring_wire_bytes(1, 4, 10 ** 6) == 0
    # f16 halves the grad wire exactly whenever the f32 count is even
    for p in (2, 3, 8):
        for n in (23, 100):
            f32b = 2 * ring_wire_bytes(p, 4, n)
            f16b = 2 * ring_wire_bytes(p, 2, n)
            assert abs(2 * f16b - f32b) <= 2, (p, n, f32b, f16b)
    print("wire formula matches rust/src/collectives/comm.rs vectors")


if __name__ == '__main__':
    check_wire_formula()
    check_proc_frame_bytes()
    for p in (1, 2, 3, 8):
        for micro in (1, 2):
            for chunk in (1, 7, 1000):
                run_case(p, micro, n_items=5, n=23, steps=4, chunk=chunk, seed=p * 10 + micro)
    # worker with no owned layers / no items
    run_case(4, 1, n_items=2, n=9, steps=6, chunk=3, seed=99)
    # mixed/f16 wire: same protocol, grad+stat counters shrink, params
    # stay f32 — compare against an identical f32 run
    for p in (2, 3, 8):
        r32 = run_case(p, 2, n_items=5, n=23, steps=4, chunk=7, seed=p, elem_bytes=4)
        r16 = run_case(p, 2, n_items=5, n=23, steps=4, chunk=7, seed=p, elem_bytes=2)
        assert r16.ar_grads * 2 <= r32.ar_grads + 2 * 4, (p, r16.ar_grads, r32.ar_grads)
        assert r16.rs_stats * 2 <= r32.rs_stats + 2 * 4, (p, r16.rs_stats, r32.rs_stats)
        assert r16.ag_params == r32.ag_params, (p, r16.ag_params, r32.ag_params)
    # zero items handled by caller skipping begin/reduce; grad+gather only
    print("ALL PROTOCOL CASES PASS")
