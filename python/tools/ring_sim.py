"""Python port of dist::RingComm's round state machines, stress-tested
with real threads to validate the synchronization protocol (deadlock
freedom, round reuse, canonical reduction results)."""
import threading, random, sys

class RingComm:
    def __init__(self, p, chunk=7):
        self.p = max(p, 1)
        self.chunk = max(chunk, 1)
        self.lock = threading.Lock()
        self.cv = threading.Condition(self.lock)
        # grad round state
        self.g = dict(active=False)
        # stat round
        self.s = dict(active=False)
        # gather round
        self.ga = dict(active=False)
        self.bytes = 0

    # ---- stat board
    def begin_stats(self, n_items, lanes):
        if n_items == 0:
            return
        with self.cv:
            assert not self.s['active'], "stat round still open"
            self.s = dict(active=True, lanes=lanes, n_items=n_items,
                          slots=[[None] * lanes for _ in range(n_items)],
                          posted=[0] * n_items, reduced=0)

    def publish_stat(self, item, lane, val):
        with self.cv:
            st = self.s
            assert st['active']
            assert st['slots'][item][lane] is None
            st['slots'][item][lane] = val
            st['posted'][item] += 1
            if st['posted'][item] == st['lanes']:
                self.cv.notify_all()

    def reduce_stat(self, item):
        with self.cv:
            st = self.s
            assert st['active']
            while st['posted'][item] < st['lanes']:
                self.cv.wait()
            taken = st['slots'][item]
            st['slots'][item] = []
        red = [sum(col) / len(taken) for col in zip(*taken)]
        with self.cv:
            st = self.s
            st['reduced'] += 1
            if st['reduced'] == st['n_items']:
                st['active'] = False
        return red

    # ---- grad AllReduce (post-by-move; one mean copy per rank drain)
    def grad_post(self, my_lanes, total):
        if not my_lanes:
            return
        n = len(my_lanes[0][1])
        with self.cv:
            while True:
                st = self.g
                if not st['active']:
                    nch = 0 if n == 0 else -(-n // self.chunk)
                    self.g = dict(active=True, n=n, total=total, posted=0,
                                  participants=0,
                                  lanes=[None] * total, frozen=None,
                                  reduced=[0.0] * n, next_chunk=0,
                                  done=0, nchunks=nch, drained=0)
                    st = self.g
                    break
                if st['posted'] < st['total']:
                    break
                self.cv.wait()
            assert st['total'] == total
            st['participants'] += 1
            for g_idx, buf in my_lanes:
                assert st['lanes'][g_idx] is None
                st['lanes'][g_idx] = buf  # moved, not copied
                st['posted'] += 1
            if st['posted'] == st['total']:
                self.cv.notify_all()

    def grad_finish(self):
        with self.cv:
            st = self.g
            assert st['active'], "finish without post"
            while st['posted'] < st['total']:
                self.cv.wait()
            if st['frozen'] is None:
                st['frozen'] = st['lanes']
                st['lanes'] = []
            frozen, n, total = st['frozen'], st['n'], st['total']
        while True:
            with self.cv:
                st = self.g
                if st['next_chunk'] >= st['nchunks']:
                    break
                c = st['next_chunk']
                st['next_chunk'] += 1
            s0 = c * self.chunk
            e0 = min(s0 + self.chunk, n)
            out = [sum(lane[i] for lane in frozen) / total
                   for i in range(s0, e0)]
            with self.cv:
                st = self.g
                st['reduced'][s0:e0] = out
                st['done'] += 1
                if st['done'] == st['nchunks']:
                    self.cv.notify_all()
        with self.cv:
            st = self.g
            while st['done'] < st['nchunks']:
                self.cv.wait()
            st['drained'] += 1
            if st['drained'] == st['participants']:
                out = st['reduced']
                st['active'] = False
                self.bytes += 2 * n
                self.cv.notify_all()
                return out
            return list(st['reduced'])

    # ---- gather
    def all_gather_v(self, rank, segs, owner_of):
        with self.cv:
            while True:
                st = self.ga
                if not st['active']:
                    self.ga = dict(active=True, n_segs=len(segs), posted=0,
                                   segs=[None] * len(segs), joined=1, drained=0)
                    st = self.ga
                    break
                if st['joined'] < self.p:
                    st['joined'] += 1
                    break
                self.cv.wait()
            assert st['n_segs'] == len(segs)
            for i, seg in enumerate(segs):
                if owner_of[i] % self.p == rank:
                    assert st['segs'][i] is None
                    st['segs'][i] = list(seg)
                    st['posted'] += 1
            if st['posted'] == st['n_segs']:
                self.cv.notify_all()
            while st['posted'] < st['n_segs']:
                self.cv.wait()
            for i in range(len(segs)):
                segs[i] = list(st['segs'][i])
            st['drained'] += 1
            if st['drained'] == self.p:
                st['active'] = False
                self.cv.notify_all()


def run_case(p, micro, n_items, n, steps, chunk, seed):
    rng = random.Random(seed)
    ring = RingComm(p, chunk)
    total = p * micro
    lane_data = [[rng.uniform(-1, 1) for _ in range(n)] for _ in range(total)]
    stat_data = [[rng.uniform(-1, 1) for _ in range(3)] for _ in range(total * n_items)]
    owners = [i % p for i in range(n_items)]
    results = {}
    errors = []
    lock = threading.Lock()

    def one_step(rank, step):
        try:
            my_lanes = [g for g in range(total) if g % p == rank]
            pubs = [(i, g) for g in my_lanes for i in range(n_items)]
            rng2 = random.Random(seed * 1000 + rank * 100 + step)
            rng2.shuffle(pubs)
            for i, g in pubs:
                ring.publish_stat(i, g, stat_data[g * n_items + i])
            lanes = [(g, list(lane_data[g])) for g in my_lanes]
            ring.grad_post(lanes, total)
            red = {}
            for i in range(n_items):
                if owners[i] == rank:
                    red[i] = ring.reduce_stat(i)
            mean = ring.grad_finish() if my_lanes else []
            segs = [[float(rank)] * (i + 1) if owners[i] % p == rank
                    else [0.0] * (i + 1) for i in range(n_items)]
            ring.all_gather_v(rank, segs, owners)
            with lock:
                for i, v in red.items():
                    results[(step, i)] = v
                results[(step, 'grad', rank)] = [list(mean)]
                results[(step, 'ag', rank)] = segs
        except Exception as e:  # noqa
            with lock:
                errors.append((rank, repr(e)))

    for step in range(steps):
        ring.begin_stats(n_items, total)
        ts = [threading.Thread(target=one_step, args=(r, step)) for r in range(p)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(timeout=60)
            if t.is_alive():
                print(f"DEADLOCK p={p} micro={micro} chunk={chunk} step={step}")
                sys.exit(1)
        if errors:
            print("ERRORS:", errors)
            sys.exit(1)

    for step in range(steps):
        for i in range(n_items):
            want = [sum(stat_data[g * n_items + i][j] for g in range(total)) / total
                    for j in range(3)]
            assert results[(step, i)] == want, (step, i)
        want_grad = [sum(lane_data[g][j] for g in range(total)) / total for j in range(n)]
        for r in range(p):
            for b in results[(step, 'grad', r)]:
                assert b == want_grad, (step, r)
            segs = results[(step, 'ag', r)]
            for i in range(n_items):
                assert segs[i] == [float(owners[i])] * (i + 1), (step, r, i)
    print(f"OK p={p} micro={micro} items={n_items} n={n} chunk={chunk} steps={steps}")


if __name__ == '__main__':
    for p in (1, 2, 3, 8):
        for micro in (1, 2):
            for chunk in (1, 7, 1000):
                run_case(p, micro, n_items=5, n=23, steps=4, chunk=chunk, seed=p * 10 + micro)
    # worker with no owned layers / no items
    run_case(4, 1, n_items=2, n=9, steps=6, chunk=3, seed=99)
    # zero items handled by caller skipping begin/reduce; grad+gather only
    print("ALL PROTOCOL CASES PASS")
