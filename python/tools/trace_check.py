#!/usr/bin/env python3
"""Validator for the observability layer's two export formats.

Chrome traces (``--trace``, written by ``spngd train --trace-out`` /
``SPNGD_TRACE``): the file must be a loadable trace-event JSON object —
every event carries a known phase (``M``/``X``/``i``/``C``), integer
pid/tid, and non-negative timestamps; span categories come from the
fixed taxonomy (phase/compute/comm/wire/data/pool); every tid is
labeled by a ``thread_name`` metadata event. ``--expect-comm``
additionally requires both comm-category and compute-category spans on
the trace (a threaded run that recorded neither is dark), recomputes
the comm-hidden fraction from the span intervals exactly like
``util::obs::overlap`` does, and prints it.

JSONL event streams (``--events``, written by ``--events-out`` /
``SPNGD_EVENTS``): every non-empty line must parse under the
``spngd-events/2`` schema (``spngd-events/1`` lines are still accepted
— /2 only added the checkpoint lifecycle kinds ``checkpoint_saved``
and ``resumed``) with a known kind and unique ``seq`` (concurrent
emitters may write out of order, so order is not checked).
``--expect-kill-recovery`` asserts the membership machine streamed a
``dead`` record followed (in seq order) by a ``respawned`` record for
the same rank — the machine-readable form of the kill-fault
acceptance scenario. ``--expect-resume`` asserts the checkpoint loop
closed: a ``checkpoint_saved`` record followed (in seq order) by a
``resumed`` record at the same step.

Usage:
    python3 python/tools/trace_check.py --trace trace.json [--expect-comm]
    python3 python/tools/trace_check.py --events events.jsonl \
        [--expect-kill-recovery] [--expect-resume]
    python3 python/tools/trace_check.py --self-test
"""

import argparse
import json
import sys

EVENT_SCHEMA = "spngd-events/2"
EVENT_SCHEMAS = {"spngd-events/1", "spngd-events/2"}
PHASES = {"M", "X", "i", "C"}
CATS = {"phase", "compute", "comm", "wire", "data", "pool"}
COMM_CATS = {"comm", "wire"}
COMPUTE_CATS = {"compute", "data", "pool"}
EVENT_KINDS = {
    "state", "joined", "dead", "respawned", "poison", "fault_plan",
    "checkpoint_saved", "resumed",
}


def union_len(intervals):
    """Total length of the union of (start, end) intervals."""
    total, last_end = 0.0, None
    for a, b in sorted(intervals):
        if b <= a:
            continue
        if last_end is None or a > last_end:
            total += b - a
            last_end = b
        elif b > last_end:
            total += b - last_end
            last_end = b
    return total


def intersection_len(xs, ys):
    xs, ys = sorted(xs), sorted(ys)
    i = j = 0
    total = 0.0
    while i < len(xs) and j < len(ys):
        lo = max(xs[i][0], ys[j][0])
        hi = min(xs[i][1], ys[j][1])
        if hi > lo:
            total += hi - lo
        if xs[i][1] < ys[j][1]:
            i += 1
        else:
            j += 1
    return total


def check_trace(doc, expect_comm, errors):
    evs = doc.get("traceEvents")
    if not isinstance(evs, list) or not evs:
        errors.append("trace: traceEvents missing or empty")
        return
    named_tids = set()
    seen_tids = set()
    comm_iv, compute_iv = [], []
    n_spans = 0
    for k, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in PHASES:
            errors.append(f"trace[{k}]: unknown ph {ph!r}")
            continue
        if not isinstance(e.get("pid"), int) or not isinstance(e.get("tid"), int):
            errors.append(f"trace[{k}]: pid/tid must be integers")
            continue
        if ph == "M":
            if e.get("name") != "thread_name":
                errors.append(f"trace[{k}]: unexpected metadata event {e.get('name')!r}")
            elif not e.get("args", {}).get("name"):
                errors.append(f"trace[{k}]: thread_name metadata without a name")
            else:
                named_tids.add(e["tid"])
            continue
        seen_tids.add(e["tid"])
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"trace[{k}]: bad ts {ts!r}")
            continue
        if not e.get("name"):
            errors.append(f"trace[{k}]: event without a name")
        if ph == "X":
            n_spans += 1
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"trace[{k}]: span {e.get('name')!r} with bad dur {dur!r}")
                continue
            cat = e.get("cat")
            if cat not in CATS:
                errors.append(f"trace[{k}]: span {e.get('name')!r} with unknown cat {cat!r}")
            elif cat in COMM_CATS:
                comm_iv.append((ts, ts + dur))
            elif cat in COMPUTE_CATS:
                compute_iv.append((ts, ts + dur))
    unnamed = seen_tids - named_tids
    if unnamed:
        errors.append(f"trace: tids without thread_name metadata: {sorted(unnamed)}")
    if n_spans == 0:
        errors.append("trace: no complete (ph=X) spans at all")
    if expect_comm:
        if not comm_iv:
            errors.append("trace: --expect-comm but no comm/wire spans recorded")
        if not compute_iv:
            errors.append("trace: --expect-comm but no compute/data/pool spans recorded")
        comm_tids = {e["tid"] for e in evs if e.get("ph") == "X" and e.get("cat") in COMM_CATS}
        compute_tids = {
            e["tid"] for e in evs if e.get("ph") == "X" and e.get("cat") in COMPUTE_CATS
        }
        if comm_iv and compute_iv and not (comm_tids or compute_tids):
            errors.append("trace: comm/compute spans landed on no lanes")
    if not errors:
        comm = union_len(comm_iv)
        hidden = intersection_len(comm_iv, compute_iv)
        frac = hidden / comm if comm else 0.0
        print(
            f"trace OK: {n_spans} spans on {len(seen_tids)} lanes, "
            f"comm {comm / 1e3:.2f} ms, hidden {frac * 100.0:.0f}%"
        )


def check_events(text, expect_kill_recovery, errors, expect_resume=False):
    recs = []
    for i, line in enumerate(text.splitlines()):
        if not line.strip():
            continue
        try:
            o = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"events:{i + 1}: unparseable line ({e})")
            continue
        if o.get("schema") not in EVENT_SCHEMAS:
            errors.append(
                f"events:{i + 1}: schema {o.get('schema')!r} not in {sorted(EVENT_SCHEMAS)}"
            )
            continue
        if o.get("kind") not in EVENT_KINDS:
            errors.append(f"events:{i + 1}: unknown kind {o.get('kind')!r}")
            continue
        if not isinstance(o.get("t"), (int, float)) or not isinstance(o.get("seq"), int):
            errors.append(f"events:{i + 1}: t/seq missing or mistyped")
            continue
        recs.append(o)
    if not recs:
        errors.append("events: stream is empty")
        return
    seqs = [r["seq"] for r in recs]
    if len(set(seqs)) != len(seqs):
        errors.append("events: duplicate seq numbers — two writers on one stream?")
    if expect_kill_recovery:
        deaths = [r for r in recs if r["kind"] == "dead"]
        if not deaths:
            errors.append("events: --expect-kill-recovery but no dead record")
        else:
            recovered = any(
                r["kind"] == "respawned"
                and r.get("rank") == d.get("rank")
                and r["seq"] > d["seq"]
                for d in deaths
                for r in recs
            )
            if not recovered:
                errors.append(
                    "events: death streamed but no respawned record for that rank followed"
                )
    if expect_resume:
        saves = [r for r in recs if r["kind"] == "checkpoint_saved"]
        if not saves:
            errors.append("events: --expect-resume but no checkpoint_saved record")
        else:
            resumed = any(
                r["kind"] == "resumed"
                and r.get("step") == s.get("step")
                and r["seq"] > s["seq"]
                for s in saves
                for r in recs
            )
            if not resumed:
                errors.append(
                    "events: checkpoint_saved streamed but no resumed record "
                    "at that step followed — the restore leg never ran"
                )
    if not errors:
        kinds = {}
        for r in recs:
            kinds[r["kind"]] = kinds.get(r["kind"], 0) + 1
        print(f"events OK: {len(recs)} records " + str(dict(sorted(kinds.items()))))


# ---------------------------------------------------------------- self-test


def synth_trace(broken=False):
    pid = 1
    evs = [
        {"ph": "M", "name": "thread_name", "pid": pid, "tid": t,
         "args": {"name": n}}
        for t, n in [(0, "main"), (1, "spngd-worker-0"), (2, "spngd-worker-1")]
    ]
    evs += [
        {"ph": "X", "name": "step", "cat": "phase", "pid": pid, "tid": 0,
         "ts": 0.0, "dur": 1000.0},
        {"ph": "X", "name": "exec_fwd_bwd", "cat": "compute", "pid": pid, "tid": 1,
         "ts": 10.0, "dur": 500.0},
        {"ph": "X", "name": "ring_wait", "cat": "comm", "pid": pid, "tid": 2,
         "ts": 100.0, "dur": 300.0},
        {"ph": "i", "name": "poison", "cat": "comm", "pid": pid, "tid": 0,
         "ts": 900.0, "s": "t"},
        {"ph": "C", "name": "live", "pid": pid, "tid": 0, "ts": 950.0,
         "args": {"value": 2.0}},
    ]
    if broken:
        evs.append({"ph": "X", "name": "bad", "cat": "nonsense", "pid": pid,
                    "tid": 7, "ts": -5.0, "dur": 1.0})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def synth_events(broken=False, broken_resume=False):
    # the first records ride the /1 schema on purpose: old streams must
    # keep validating after the /2 bump
    lines = [
        {"schema": "spngd-events/1", "seq": 0, "t": 0.1, "kind": "state",
         "state": "WaitingForMembers", "step": 0},
        {"schema": "spngd-events/1", "seq": 1, "t": 0.2, "kind": "joined", "rank": 0,
         "uid": 17, "step": 0},
        {"schema": EVENT_SCHEMA, "seq": 2, "t": 0.9, "kind": "dead", "rank": 1,
         "step": 2, "reason": "heartbeat timeout"},
        {"schema": EVENT_SCHEMA, "seq": 3, "t": 1.1, "kind": "respawned",
         "rank": 1, "attempt": 1},
        {"schema": EVENT_SCHEMA, "seq": 4, "t": 1.5, "kind": "checkpoint_saved",
         "step": 3, "path": "ckpt/ckpt-000000000003.spck"},
        {"schema": EVENT_SCHEMA, "seq": 5, "t": 1.7, "kind": "resumed",
         "step": 3, "path": "ckpt/ckpt-000000000003.spck"},
    ]
    if broken:
        lines = lines[:3]  # death with no recovery
    if broken_resume:
        lines = lines[:5]  # checkpoint saved, restore leg never ran
    return "\n".join(json.dumps(o) for o in lines) + "\n"


def self_test():
    errors = []
    check_trace(synth_trace(), expect_comm=True, errors=errors)
    if errors:
        print("self-test FAILED: healthy synthetic trace rejected:", errors)
        return 1
    bad = []
    check_trace(synth_trace(broken=True), expect_comm=True, errors=bad)
    if not bad:
        print("self-test FAILED: broken trace accepted")
        return 1
    errors = []
    check_events(synth_events(), expect_kill_recovery=True, errors=errors,
                 expect_resume=True)
    if errors:
        print("self-test FAILED: healthy synthetic events rejected:", errors)
        return 1
    bad = []
    check_events(synth_events(broken=True), expect_kill_recovery=True, errors=bad)
    if not bad:
        print("self-test FAILED: unrecovered death accepted")
        return 1
    bad = []
    check_events(synth_events(broken_resume=True), expect_kill_recovery=False,
                 errors=bad, expect_resume=True)
    if not bad:
        print("self-test FAILED: save-without-resume accepted under --expect-resume")
        return 1
    bad = []
    check_events(json.dumps({"schema": "spngd-events/9", "seq": 0, "t": 0.0,
                             "kind": "state"}) + "\n",
                 expect_kill_recovery=False, errors=bad)
    if not bad:
        print("self-test FAILED: unknown event schema accepted")
        return 1
    print("self-test OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--trace", help="Chrome trace-event JSON to validate")
    ap.add_argument("--expect-comm", action="store_true",
                    help="require comm AND compute spans; report the hidden fraction")
    ap.add_argument("--events", help="JSONL event stream to validate")
    ap.add_argument("--expect-kill-recovery", action="store_true",
                    help="require a dead record followed by a respawned record")
    ap.add_argument("--expect-resume", action="store_true",
                    help="require a checkpoint_saved record followed by a "
                         "resumed record at the same step")
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args()

    if args.self_test:
        sys.exit(self_test())
    if not args.trace and not args.events:
        ap.error("nothing to check: pass --trace and/or --events (or --self-test)")

    errors = []
    if args.trace:
        try:
            with open(args.trace) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"trace: cannot load {args.trace}: {e}")
        else:
            check_trace(doc, args.expect_comm, errors)
    if args.events:
        try:
            with open(args.events) as f:
                text = f.read()
        except OSError as e:
            errors.append(f"events: cannot load {args.events}: {e}")
        else:
            check_events(text, args.expect_kill_recovery, errors,
                         expect_resume=args.expect_resume)

    if errors:
        print(f"trace_check: FAIL ({len(errors)} problem(s))")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)


if __name__ == "__main__":
    main()
