#!/usr/bin/env python3
"""Bench-regression gate for BENCH_native.json (schema spngd-bench-native/6).

CI runs `cargo bench --bench native_perf -- --quick`, then this gate
compares the report against the committed baseline
(rust/benches/baseline/BENCH_baseline.json) and exits nonzero on
regression. Three independent checks, ordered from robust to advisory:

1. **Speedup floors** (primary ratchet, machine-independent): every
   report entry carries `speedup` = naive_ns / ns measured *in the same
   process on the same machine*, so the ratio survives CI hardware
   churn. Each baseline rule `{section, match, min_speedup}` must match
   at least one report entry (prefix match on `name`) and every matched
   entry must clear the floor. SIMD entries that resolved to the
   `scalar` kernel (no vector unit on the runner) are exempt from their
   floor — there is nothing to gate.

2. **Structural gates** (exact, deterministic): the mixed-precision
   wire format must actually shrink the gradient/statistics payloads
   (byte counters, not timings — ratio <= 0.55 vs f32 at 2 workers,
   where halving is exact) while parameters stay f32 (ratio == 1).
   The `obs` section adds tracing gates: a disabled span must stay a
   branch on an atomic (`disabled_span_ns` capped), tracing-on must not
   balloon the step (`trace_overhead_ratio` capped), and the overlap
   accountant's sums must be internally consistent (hidden <= comm,
   max(comm, compute) <= critical path <= comm + compute, a traced
   threaded run records both comm and compute spans).
   The `serve` section gates the micro-batching queue with exact row
   accounting: every single-row request must come back exactly once
   (rows == requests), cap 1 must forward every row alone
   (batches == rows), percentiles must be ordered (p50 <= p99), and
   the batcher must actually run (batches >= 1, throughput > 0).

3. **Provisional absolute-ns** (advisory ratchet): if the baseline's
   `provisional_ns.entries` is non-empty (populated by
   `--update-baseline` on a quiet reference machine), each entry's `ns`
   must stay under baseline * tolerance. Empty by default because
   absolute times are machine-bound; enable deliberately.

Usage:
    python3 python/tools/bench_gate.py --report BENCH_native.json
    python3 python/tools/bench_gate.py --report ... --update-baseline
    python3 python/tools/bench_gate.py --self-test

`--update-baseline` re-ratchets: floors rise to measured/1.15 (never
loosen without --allow-loosen) and provisional ns entries are refreshed.
`--self-test` needs no report: it synthesizes a conforming report from
the baseline (must PASS), then a 10x-slowed / non-shrinking variant
(must FAIL) — the negative test CI runs to prove the gate has teeth.
"""

import argparse
import copy
import json
import sys

DEFAULT_BASELINE = "rust/benches/baseline/BENCH_baseline.json"
REPORT_SCHEMA = "spngd-bench-native/6"
REQUIRED_SECTIONS = [
    "kernels", "workers", "optimizers", "data", "simd", "precision", "obs", "serve",
]
RATCHET_MARGIN = 1.15  # floors sit measured/1.15 below the reference run


def load(path):
    with open(path) as f:
        return json.load(f)


def section_entries(report, section):
    """Entries of a report section as a list ('step'/'obs' are single objects)."""
    if section in ("step", "obs", "serve"):
        return [report[section]] if report.get(section) else []
    return list(report.get(section, []))


def check_schema(report, errors):
    if report.get("schema") != REPORT_SCHEMA:
        errors.append(
            f"schema: expected {REPORT_SCHEMA!r}, got {report.get('schema')!r} "
            "(bench runner and gate disagree — update both together)"
        )
        return False
    if "step" not in report:
        errors.append("schema: missing 'step' section")
    for s in REQUIRED_SECTIONS:
        if not report.get(s):
            errors.append(f"schema: section '{s}' missing or empty")
    return not errors


def check_floors(report, baseline, errors):
    for rule in baseline.get("speedup_floors", []):
        section, prefix, floor = rule["section"], rule["match"], rule["min_speedup"]
        matched = [e for e in section_entries(report, section) if e["name"].startswith(prefix)]
        if not matched:
            errors.append(
                f"floor[{section}/{prefix!r}]: no report entry matches — "
                "kernel renamed or dropped without updating the baseline"
            )
            continue
        for e in matched:
            if section == "simd" and e.get("kernel") == "scalar":
                continue  # no vector unit on this runner: nothing to ratchet
            sp = e["speedup"]
            if sp < floor:
                errors.append(
                    f"floor[{section}/{e['name']}]: speedup {sp:.3f} < floor {floor:.2f} "
                    f"(ns={e.get('ns', 0):.0f})"
                )


def precision_rows(report):
    rows = {e["precision"]: e for e in report.get("precision", [])}
    return rows.get("f32"), rows.get("mixed")


def check_structural(report, baseline, errors):
    st = baseline.get("structural", {})
    f32, mixed = precision_rows(report)
    if f32 is None or mixed is None:
        errors.append("structural: precision section must contain both 'f32' and 'mixed' rows")
        return
    for field, key in [
        ("grad_bytes_per_step", "mixed_grad_ratio_max"),
        ("stats_bytes_per_step", "mixed_stats_ratio_max"),
    ]:
        cap = st.get(key)
        if cap is None:
            continue
        denom = f32[field]
        ratio = mixed[field] / denom if denom else 1.0
        if ratio > cap:
            errors.append(
                f"structural: mixed {field} ratio {ratio:.3f} > {cap} — "
                "the f16 wire format is not shrinking the payload"
            )
    lo, hi = st.get("param_ratio_min", 0.0), st.get("param_ratio_max", float("inf"))
    denom = f32["param_bytes_per_step"]
    pr = mixed["param_bytes_per_step"] / denom if denom else 1.0
    if not lo <= pr <= hi:
        errors.append(
            f"structural: param byte ratio {pr:.3f} outside [{lo}, {hi}] — "
            "parameters must keep travelling f32 under mixed"
        )


def check_obs(report, baseline, errors):
    obs = report.get("obs")
    if not isinstance(obs, dict):
        errors.append("obs: section must be a single object")
        return
    gate = baseline.get("obs_gate", {})
    required = [
        "disabled_span_ns", "step_ns", "step_ns_traced", "trace_overhead_ratio",
        "events", "comm_ns", "compute_ns", "hidden_ns", "hidden_fraction",
        "critical_path_ns",
    ]
    missing = [k for k in required if k not in obs]
    if missing:
        errors.append(f"obs: missing fields {missing}")
        return
    cap = gate.get("disabled_span_ns_max")
    if cap is not None and obs["disabled_span_ns"] > cap:
        errors.append(
            f"obs: disabled span costs {obs['disabled_span_ns']:.1f} ns > {cap} — "
            "the tracing-off fast path must stay a branch on an atomic"
        )
    cap = gate.get("trace_overhead_ratio_max")
    if cap is not None and obs["trace_overhead_ratio"] > cap:
        errors.append(
            f"obs: traced/untraced step ratio {obs['trace_overhead_ratio']:.2f} > {cap} — "
            "recording spans is slowing the step down"
        )
    # internal consistency of the overlap accountant (exact invariants)
    comm, compute = obs["comm_ns"], obs["compute_ns"]
    hidden, crit = obs["hidden_ns"], obs["critical_path_ns"]
    if obs["events"] <= 0:
        errors.append("obs: traced run recorded zero events — instrumentation is dark")
    if comm <= 0 or compute <= 0:
        errors.append(
            f"obs: traced threaded run must record both comm ({comm:.0f} ns) and "
            f"compute ({compute:.0f} ns) spans"
        )
    if hidden > min(comm, compute) + 1:
        errors.append(f"obs: hidden {hidden:.0f} ns exceeds min(comm, compute)")
    if not (max(comm, compute) - 1 <= crit <= comm + compute + 1):
        errors.append(
            f"obs: critical path {crit:.0f} ns outside [max(comm, compute), comm + compute]"
        )
    if not 0.0 <= obs["hidden_fraction"] <= 1.0:
        errors.append(f"obs: hidden_fraction {obs['hidden_fraction']} outside [0, 1]")


def check_serve(report, errors):
    """Exact row accounting for the serving queue — no timing floors,
    so the gate never flakes on a loaded CI box."""
    serve = report.get("serve")
    if not isinstance(serve, dict):
        errors.append("serve: section must be a single object")
        return
    fwd = serve.get("forward", [])
    if len(fwd) < 2:
        errors.append("serve: forward must time both a 1-row and a full-batch pass")
    for e in fwd:
        if e.get("ns", 0) <= 0 or e.get("ns_per_row", 0) <= 0:
            errors.append(f"serve: forward entry rows={e.get('rows')} has non-positive timings")
    queue = serve.get("queue", [])
    if not queue:
        errors.append("serve: queue sweep is empty — the batcher was never exercised")
    for q in queue:
        mb = q.get("max_batch", 0)
        tag = f"serve queue[max_batch={mb}]"
        requests, batches, rows = q.get("requests", 0), q.get("batches", 0), q.get("rows", 0)
        if requests <= 0:
            errors.append(f"{tag}: no requests completed")
            continue
        if batches < 1 or rows <= 0:
            errors.append(
                f"{tag}: {batches} batches over {rows} rows — the batcher is not flushing"
            )
        if rows != requests:
            errors.append(
                f"{tag}: {rows} rows predicted for {requests} single-row requests — "
                "requests lost or duplicated in the queue"
            )
        if mb == 1 and batches != rows:
            errors.append(
                f"{tag}: cap 1 must forward every row alone, "
                f"got {batches} batches for {rows} rows"
            )
        p50, p99 = q.get("p50_ns", 0), q.get("p99_ns", 0)
        if p50 <= 0 or p99 < p50:
            errors.append(f"{tag}: latency percentiles inconsistent (p50 {p50}, p99 {p99})")
        if q.get("throughput_rps", 0) <= 0:
            errors.append(f"{tag}: non-positive throughput")


def check_provisional_ns(report, baseline, errors):
    prov = baseline.get("provisional_ns", {})
    tol = prov.get("tolerance", 3.0)
    entries = prov.get("entries", {})
    by_name = {}
    for section in ["step"] + REQUIRED_SECTIONS:
        for e in section_entries(report, section):
            if "name" in e and "ns" in e:
                by_name[e["name"]] = e["ns"]
    for name, base_ns in entries.items():
        got = by_name.get(name)
        if got is None:
            errors.append(f"provisional[{name}]: entry vanished from the report")
        elif got > base_ns * tol:
            errors.append(
                f"provisional[{name}]: {got:.0f} ns > {base_ns:.0f} * {tol} — "
                "absolute regression beyond tolerance"
            )


def run_gate(report, baseline):
    errors = []
    if check_schema(report, errors):
        check_floors(report, baseline, errors)
        check_structural(report, baseline, errors)
        check_obs(report, baseline, errors)
        check_serve(report, errors)
        check_provisional_ns(report, baseline, errors)
    return errors


def update_baseline(report, baseline, allow_loosen):
    """Re-ratchet floors to measured/RATCHET_MARGIN; refresh provisional ns."""
    changed = []
    for rule in baseline.get("speedup_floors", []):
        section, prefix = rule["section"], rule["match"]
        matched = [e for e in section_entries(report, section) if e["name"].startswith(prefix)]
        gateable = [
            e for e in matched if not (section == "simd" and e.get("kernel") == "scalar")
        ]
        if not gateable:
            continue
        measured = min(e["speedup"] for e in gateable)
        proposed = round(measured / RATCHET_MARGIN, 2)
        old = rule["min_speedup"]
        if proposed > old or allow_loosen:
            rule["min_speedup"] = proposed
            changed.append(f"floor[{section}/{prefix!r}]: {old:.2f} -> {proposed:.2f}")
    prov = baseline.setdefault("provisional_ns", {"tolerance": 3.0, "entries": {}})
    entries = {}
    for section in ["step"] + REQUIRED_SECTIONS:
        for e in section_entries(report, section):
            if "name" in e and "ns" in e:
                entries[e["name"]] = round(e["ns"], 1)
    prov["entries"] = entries
    changed.append(f"provisional_ns: {len(entries)} entries refreshed")
    return changed


def synth_report(baseline, slowed=False):
    """Fabricate a report straight from the baseline's own rules.

    The healthy variant clears every floor by 1.5x and halves the mixed
    byte counters; the slowed variant multiplies ns by 10 (speedup /10)
    and ships mixed bytes at the f32 size — the gate must reject it.
    """
    factor = 10.0 if slowed else 1.0
    report = {"schema": REPORT_SCHEMA, "step": None}
    for s in REQUIRED_SECTIONS:
        report[s] = []
    for rule in baseline.get("speedup_floors", []):
        section, prefix, floor = rule["section"], rule["match"], rule["min_speedup"]
        speedup = floor * 1.5 / factor
        entry = {
            "name": prefix + " synthetic",
            "ns": 1000.0 * factor,
            "naive_ns": 1000.0 * floor * 1.5,
            "speedup": speedup,
        }
        if section == "simd":
            entry["kernel"] = "avx2"
            entry["scalar_ns"] = entry.pop("naive_ns")
        if section == "step":
            report["step"] = entry
        else:
            report[section].append(entry)
    if report["step"] is None:
        report["step"] = {"name": "step synthetic", "ns": 1.0, "naive_ns": 2.0, "speedup": 2.0}
    # healthy obs: cheap disabled spans, near-free tracing, consistent
    # overlap sums; slowed obs: a disabled span that costs a mutex and a
    # traced step 5x the untraced one — both capped by obs_gate
    report["obs"] = {
        "disabled_span_ns": 2000.0 if slowed else 5.0,
        "step_ns": 1.0e6,
        "step_ns_traced": 5.0e6 if slowed else 1.05e6,
        "trace_overhead_ratio": 5.0 if slowed else 1.05,
        "events": 4000,
        "dropped": 0,
        "comm_ns": 1.0e6,
        "compute_ns": 5.0e6,
        "hidden_ns": 6.0e5,
        "hidden_fraction": 0.6,
        "critical_path_ns": 5.4e6,
    }
    shrink = 1.0 if slowed else 0.5
    report["precision"] = [
        {
            "precision": "f32",
            "step_ns": 1000.0,
            "grad_bytes_per_step": 1.0e6,
            "stats_bytes_per_step": 4.0e5,
            "param_bytes_per_step": 2.0e6,
        },
        {
            "precision": "mixed",
            "step_ns": 900.0,
            "grad_bytes_per_step": 1.0e6 * shrink,
            "stats_bytes_per_step": 4.0e5 * shrink,
            "param_bytes_per_step": 2.0e6,
        },
    ]
    # healthy serve: every single-row request accounted for, cap 1 runs
    # one forward per row, percentiles ordered; slowed serve: a dead
    # batcher that lost every request with inverted percentiles
    if slowed:
        serve_queue = [
            {"max_batch": 1, "requests": 64, "batches": 0, "rows": 0,
             "p50_ns": 9.0e5, "p99_ns": 2.0e5, "throughput_rps": 0.0},
        ]
    else:
        serve_queue = [
            {"max_batch": 1, "requests": 64, "batches": 64, "rows": 64,
             "p50_ns": 2.0e5, "p99_ns": 8.0e5, "throughput_rps": 5000.0},
            {"max_batch": 8, "requests": 64, "batches": 12, "rows": 64,
             "p50_ns": 4.0e5, "p99_ns": 9.0e5, "throughput_rps": 9000.0},
        ]
    report["serve"] = {
        "model": "synthetic",
        "batch": 8,
        "forward": [
            {"rows": 1, "ns": 1.0e5, "ns_per_row": 1.0e5},
            {"rows": 8, "ns": 2.0e5, "ns_per_row": 2.5e4},
        ],
        "queue": serve_queue,
    }
    for s in ["workers", "optimizers", "data"]:
        if not report[s]:
            report[s] = [{"name": f"{s} synthetic", "step_ns": 1.0}]
    return report


def self_test(baseline):
    ok = run_gate(synth_report(baseline, slowed=False), baseline)
    if ok:
        print("self-test FAILED: healthy synthetic report was rejected:")
        for e in ok:
            print(f"  - {e}")
        return 1
    print("self-test: healthy synthetic report PASSES the gate (as it must)")
    bad = run_gate(synth_report(baseline, slowed=True), copy.deepcopy(baseline))
    if not bad:
        print("self-test FAILED: 10x-slowed report sailed through — the gate has no teeth")
        return 1
    print(f"self-test: slowed/non-shrinking report FAILS the gate with {len(bad)} errors (good):")
    for e in bad[:4]:
        print(f"  - {e}")
    print("self-test OK")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--report", default="BENCH_native.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--allow-loosen", action="store_true",
                    help="with --update-baseline, let floors drop (default: ratchet only)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate accepts a healthy report and rejects a slowed one")
    args = ap.parse_args()

    baseline = load(args.baseline)
    if args.self_test:
        sys.exit(self_test(baseline))

    report = load(args.report)
    if args.update_baseline:
        errors = run_gate(report, copy.deepcopy(baseline))
        structural = [e for e in errors if e.startswith(("structural", "schema"))]
        if structural:
            print("refusing to ratchet from a structurally broken report:")
            for e in structural:
                print(f"  - {e}")
            sys.exit(1)
        for line in update_baseline(report, baseline, args.allow_loosen):
            print(line)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"wrote {args.baseline}")
        sys.exit(0)

    errors = run_gate(report, baseline)
    if errors:
        print(f"bench gate: FAIL ({len(errors)} regression(s) vs {args.baseline})")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    n_floors = len(baseline.get("speedup_floors", []))
    print(f"bench gate: PASS ({n_floors} speedup floors, structural byte gates, "
          f"obs tracing gates, "
          f"{len(baseline.get('provisional_ns', {}).get('entries', {}))} provisional ns entries)")


if __name__ == "__main__":
    main()
