use std::rc::Rc;
use spngd::coordinator::{BnMode, Fisher, Optim, Trainer, TrainerCfg};
use spngd::data::{AugmentCfg, SynthDataset};
use spngd::optim::{HyperParams, Schedule};
use spngd::runtime::{Engine, Manifest};
fn main() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Rc::new(Manifest::load(&dir).unwrap());
    let engine = Rc::new(Engine::new(&manifest).unwrap());
    let m = manifest.model("mlp").unwrap();
    let ds = SynthDataset::new(m.num_classes, 3, 8, 8, 4000, 42);
    let hp = HyperParams { alpha_mixup: 0.0, p_decay: 2.0, e_start: 100.0, e_end: 200.0,
        eta0: 0.02, m0: 0.018, lambda: 2.5e-3 };
    let cfg = TrainerCfg { model: "mlp".into(), workers: 2, grad_accum: 4,
        fisher: Fisher::Emp, bn_mode: BnMode::Unit, stale: true, stale_alpha: 0.3,
        lambda: hp.lambda, schedule: Schedule::new(hp, 50), optimizer: Optim::SpNgd,
        weight_rescale: false, augment: AugmentCfg::disabled(), bn_momentum: 0.9, seed: 7 };
    let mut tr = Trainer::new(manifest, engine, cfg, ds).unwrap();
    for _ in 0..30 {
        let r = tr.step().unwrap();
        println!("step {:2} loss {:.4} acc {:.3} refreshed {}/{}", r.step, r.loss, r.train_acc, r.refreshed, r.total_stats);
    }
}
