//! CLI for spngd-lint.
//!
//! ```text
//! spngd-lint [--root DIR] [--config FILE] [--self-test]
//! ```
//!
//! Exit codes: 0 clean, 1 findings (or self-test failure), 2 usage or
//! config error. Deny-by-default: there is no warning mode and no
//! `--fix` — suppression happens in source (pragmas) or `lint.toml`,
//! where review can see it.

use spngd_lint::Config;
use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> &'static str {
    "usage: spngd-lint [--root DIR] [--config FILE] [--self-test]"
}

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut config: Option<PathBuf> = None;
    let mut self_test = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(v) => root = PathBuf::from(v),
                None => {
                    eprintln!("{}", usage());
                    return ExitCode::from(2);
                }
            },
            "--config" => match args.next() {
                Some(v) => config = Some(PathBuf::from(v)),
                None => {
                    eprintln!("{}", usage());
                    return ExitCode::from(2);
                }
            },
            "--self-test" => self_test = true,
            "-h" | "--help" => {
                println!("{}", usage());
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("spngd-lint: unknown argument `{other}`\n{}", usage());
                return ExitCode::from(2);
            }
        }
    }

    if self_test {
        let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        return match spngd_lint::self_test(&manifest) {
            Ok(msg) => {
                println!("spngd-lint: {msg}");
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("spngd-lint: self-test FAILED: {e}");
                ExitCode::from(1)
            }
        };
    }

    let cfg_path = config.unwrap_or_else(|| root.join("lint.toml"));
    let cfg = match Config::load(&cfg_path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("spngd-lint: {e}");
            return ExitCode::from(2);
        }
    };
    match spngd_lint::run(&root, &cfg) {
        Ok(findings) if findings.is_empty() => {
            println!("spngd-lint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{}", f.render());
            }
            eprintln!("spngd-lint: {} finding(s)", findings.len());
            ExitCode::from(1)
        }
        Err(e) => {
            eprintln!("spngd-lint: {e}");
            ExitCode::from(2)
        }
    }
}
