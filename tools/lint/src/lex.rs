//! A comment/string-aware line lexer for Rust sources — just enough of
//! the token grammar that rules never match text inside comments,
//! string/char literals or raw strings, and string contents can be
//! scanned separately (the env-registry rule reads `"SPNGD_*"`
//! literals). Not a parser: it tracks five states (code, line comment,
//! nested block comment, string, raw string) plus char-vs-lifetime
//! disambiguation, and blanks everything that is not code out of the
//! per-line `code` text.

/// One source line, split by lexical class.
#[derive(Debug, Default, Clone)]
pub struct LineInfo {
    /// Code text with comments removed and string/char contents blanked.
    pub code: String,
    /// Comment text on this line (`//`, `///`, `/* .. */` bodies).
    pub comment: String,
    /// Contents of string literals that start or continue on this line.
    pub strings: Vec<String>,
}

fn is_ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Lex a whole file into per-line [`LineInfo`] records. Total over
/// arbitrary input: unterminated literals and comments simply run to
/// end-of-file.
pub fn lex(text: &str) -> Vec<LineInfo> {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment,
        Str,
        RawStr,
    }
    let b = text.as_bytes();
    let n = b.len();
    let mut lines = Vec::new();
    let mut cur = LineInfo::default();
    let mut curstr: Option<Vec<u8>> = None;
    let mut state = St::Code;
    let mut depth = 0usize; // block-comment nesting
    let mut raw_hashes = 0usize;
    let mut i = 0usize;

    macro_rules! push_code {
        ($s:expr) => {
            cur.code.push_str($s)
        };
    }

    while i < n {
        let c = b[i];
        if c == b'\n' {
            if state == St::LineComment {
                state = St::Code;
            }
            if let Some(s) = curstr.as_mut() {
                // a multi-line string: credit the part on this line
                cur.strings.push(String::from_utf8_lossy(s).into_owned());
                s.clear();
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match state {
            St::Code => {
                if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
                    state = St::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    state = St::BlockComment;
                    depth = 1;
                    i += 2;
                } else if c == b'"' {
                    state = St::Str;
                    curstr = Some(Vec::new());
                    push_code!("\"");
                    i += 1;
                } else if c == b'r'
                    && (i == 0
                        || !is_ident(b[i - 1])
                        || (b[i - 1] == b'b' && (i < 2 || !is_ident(b[i - 2]))))
                {
                    // raw string r"..." / r#"..."# / br#"..."#
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && b[j] == b'#' {
                        h += 1;
                        j += 1;
                    }
                    if j < n && b[j] == b'"' {
                        state = St::RawStr;
                        raw_hashes = h;
                        curstr = Some(Vec::new());
                        push_code!("r\"");
                        i = j + 1;
                    } else {
                        cur.code.push('r');
                        i += 1;
                    }
                } else if c == b'\'' {
                    if i + 1 < n && b[i + 1] == b'\\' {
                        // escaped char literal: scan to the closing quote
                        let mut j = i + 2;
                        if j < n {
                            j += 1; // the escaped character itself
                            while j < n && b[j] != b'\'' && b[j] != b'\n' {
                                j += 1;
                            }
                        }
                        push_code!("' '");
                        i = (j + 1).min(n);
                    } else if i + 2 < n && b[i + 2] == b'\'' {
                        // plain char literal 'x' (covers '"' and b'"')
                        push_code!("' '");
                        i += 3;
                    } else {
                        // lifetime tick
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c as char);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c as char);
                i += 1;
            }
            St::BlockComment => {
                if c == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                    if depth == 0 {
                        state = St::Code;
                    }
                } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else {
                    cur.comment.push(c as char);
                    i += 1;
                }
            }
            St::Str => {
                if c == b'\\' {
                    if i + 1 < n && b[i + 1] != b'\n' {
                        if let Some(s) = curstr.as_mut() {
                            s.push(b[i + 1]);
                        }
                        i += 2;
                    } else {
                        i += 1; // line-continuation escape
                    }
                } else if c == b'"' {
                    if let Some(s) = curstr.take() {
                        cur.strings.push(String::from_utf8_lossy(&s).into_owned());
                    }
                    push_code!("\"");
                    state = St::Code;
                    i += 1;
                } else {
                    if let Some(s) = curstr.as_mut() {
                        s.push(c);
                    }
                    i += 1;
                }
            }
            St::RawStr => {
                if c == b'"' {
                    let mut j = i + 1;
                    let mut h = 0usize;
                    while j < n && b[j] == b'#' && h < raw_hashes {
                        h += 1;
                        j += 1;
                    }
                    if h == raw_hashes {
                        if let Some(s) = curstr.take() {
                            cur.strings.push(String::from_utf8_lossy(&s).into_owned());
                        }
                        push_code!("\"");
                        state = St::Code;
                        i = j;
                    } else {
                        if let Some(s) = curstr.as_mut() {
                            s.push(c);
                        }
                        i += 1;
                    }
                } else {
                    if let Some(s) = curstr.as_mut() {
                        s.push(c);
                    }
                    i += 1;
                }
            }
        }
    }
    if let Some(s) = curstr.take() {
        cur.strings.push(String::from_utf8_lossy(&s).into_owned());
    }
    lines.push(cur);
    lines
}

/// Mark every line inside a `#[cfg(test)] mod … { … }` region. Test
/// code legitimately unwraps, spawns throwaway threads and prints — the
/// rules that police library code skip these lines.
pub fn test_regions(lines: &[LineInfo]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0usize;
    while i < lines.len() {
        let squished: String = lines[i].code.chars().filter(|c| !c.is_whitespace()).collect();
        if squished.contains("#[cfg(test)]") {
            let mut depth = 0i64;
            let mut opened = false;
            let mut j = i;
            while j < lines.len() {
                for ch in lines[j].code.chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                in_test[j] = true;
                if opened && depth <= 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    in_test
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_not_code() {
        let src = "let s = \"panic!\"; // unwrap()\nlet c = '\"';\nlet r = r#\"x \" y\"#;\n";
        let l = lex(src);
        assert!(!l[0].code.contains("panic!"));
        assert!(l[0].comment.contains("unwrap()"));
        assert_eq!(l[0].strings, vec!["panic!".to_string()]);
        // the char-literal quote must not open a string
        assert!(l[1].strings.is_empty());
        assert_eq!(l[2].strings, vec!["x \" y".to_string()]);
    }

    #[test]
    fn nested_block_comments_and_lifetimes() {
        let src = "/* a /* b */ still */ fn f<'a>(x: &'a [u8]) {}\n";
        let l = lex(src);
        assert!(l[0].code.contains("fn f<'a>"));
        assert!(l[0].comment.contains("b"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n  fn b() {}\n}\nfn c() {}\n";
        let l = lex(src);
        let t = test_regions(&l);
        assert_eq!(t, vec![false, true, true, true, true, false]);
    }
}
