//! spngd-lint: repo-invariant static analysis for the spngd workspace.
//!
//! A dependency-free, comment/string-aware scanner that walks the
//! scoped source trees (`rust/src`, `rust/tests`) and enforces the
//! invariants accumulated over PRs 1–9:
//!
//! - `panic-hygiene` — no `unwrap`/`expect`/`panic!`/bare indexing in
//!   the structured-error parser modules (wire, ckpt, json, f16,
//!   events, serve HTTP).
//! - `determinism` — no `Instant`/`SystemTime`/`HashMap`/`HashSet` in
//!   step-math and dist reduction paths outside the allowlist.
//! - `unsafe-audit` — every `unsafe` carries an adjacent `// SAFETY:`
//!   comment (or a `# Safety` doc section).
//! - `thread-naming` — every spawned thread is named.
//! - `no-raw-print` — no `println!`-family macros in library code.
//! - `env-registry` — every `SPNGD_*` env var read in source appears in
//!   the registry table the README renders, and vice versa.
//!
//! Suppression is explicit and audited: inline
//! `// lint:allow(<rule>) -- <reason>` pragmas (reason mandatory) and
//! per-rule allowlists in the committed `lint.toml`. Exit is
//! deny-by-default; `self_test` proves every `fixtures/bad_*.rs` trips
//! exactly its rule and `fixtures/good_clean.rs` trips none.

pub mod config;
pub mod lex;
pub mod rules;

pub use config::{Config, RuleCfg, KNOWN_RULES};
pub use rules::{Finding, Pragmas};

use rules::EnvRead;
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// Run every configured rule over `root`. Returns findings sorted by
/// (file, line, rule); empty means the tree is clean.
pub fn run(root: &Path, cfg: &Config) -> Result<Vec<Finding>, String> {
    let mut files: BTreeSet<String> = BTreeSet::new();
    for rc in cfg.rules.values() {
        for entry in &rc.scope {
            let p = root.join(entry);
            if p.is_file() {
                files.insert(entry.clone());
            } else if p.is_dir() {
                walk(&p, root, &mut files)?;
            } else {
                return Err(format!(
                    "scope entry `{entry}` does not exist under {}",
                    root.display()
                ));
            }
        }
    }

    let mut findings = Vec::new();
    let mut reads: Vec<EnvRead> = Vec::new();
    for rel in &files {
        let text = std::fs::read_to_string(root.join(rel))
            .map_err(|e| format!("cannot read {rel}: {e}"))?;
        findings.extend(rules::scan_file(rel, &text, cfg, &mut reads));
    }

    let er = cfg.rule("env-registry");
    if let Some(reg) = &er.registry {
        findings.extend(registry_check(root, reg, &reads)?);
    }

    findings.sort();
    findings.dedup();
    Ok(findings)
}

/// Cross-check collected `SPNGD_*` reads against the registry table:
/// table rows are the markdown lines starting with `|` in `reg`. Both
/// directions are enforced — an unregistered read and a stale registry
/// row are each findings.
fn registry_check(root: &Path, reg: &str, reads: &[EnvRead]) -> Result<Vec<Finding>, String> {
    let text = std::fs::read_to_string(root.join(reg))
        .map_err(|e| format!("cannot read env registry {reg}: {e}"))?;
    let mut registered: BTreeMap<String, usize> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        if !line.trim_start().starts_with('|') {
            continue;
        }
        for var in rules::env_vars(line) {
            registered.entry(var).or_insert(i + 1);
        }
    }

    let mut findings = Vec::new();
    let mut seen_reads: BTreeSet<String> = BTreeSet::new();
    let mut reported: BTreeSet<(String, String)> = BTreeSet::new();
    for r in reads {
        seen_reads.insert(r.var.clone());
        if !registered.contains_key(&r.var) && reported.insert((r.file.clone(), r.var.clone())) {
            findings.push(Finding {
                file: r.file.clone(),
                line: r.line,
                rule: "env-registry".into(),
                msg: format!(
                    "env var `{}` is read here but missing from the {reg} registry table",
                    r.var
                ),
            });
        }
    }
    for (var, line) in &registered {
        if !seen_reads.contains(var) {
            findings.push(Finding {
                file: reg.to_string(),
                line: *line,
                rule: "env-registry".into(),
                msg: format!("registry lists `{var}` but no source string references it"),
            });
        }
    }
    Ok(findings)
}

fn walk(dir: &Path, root: &Path, out: &mut BTreeSet<String>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    for e in rd {
        let e = e.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        let p = e.path();
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            let rel = p
                .strip_prefix(root)
                .map_err(|e| format!("strip_prefix {}: {e}", p.display()))?;
            out.insert(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

/// Expected (fixture file, rule tripped) pairs for the negative
/// self-test. `self_test` also checks this table is complete against
/// the fixtures directory in both directions.
pub const FIXTURE_EXPECT: &[(&str, &str)] = &[
    ("bad_determinism.rs", "determinism"),
    ("bad_env_registry.rs", "env-registry"),
    ("bad_panic_hygiene.rs", "panic-hygiene"),
    ("bad_pragma.rs", "pragma"),
    ("bad_raw_print.rs", "no-raw-print"),
    ("bad_thread_naming.rs", "thread-naming"),
    ("bad_unsafe_audit.rs", "unsafe-audit"),
];

/// Fixture-based negative self-test: every `fixtures/bad_*.rs` must
/// trip exactly its expected rule (no more, no less), and
/// `fixtures/good_clean.rs` — a lexer stress file full of forbidden
/// tokens inside strings and comments — must trip nothing.
pub fn self_test(manifest_dir: &Path) -> Result<String, String> {
    let fixtures = manifest_dir.join("fixtures");
    let mut on_disk: BTreeSet<String> = BTreeSet::new();
    let rd = std::fs::read_dir(&fixtures)
        .map_err(|e| format!("cannot read fixtures dir {}: {e}", fixtures.display()))?;
    for e in rd {
        let e = e.map_err(|e| format!("fixtures dir: {e}"))?;
        let name = e.file_name().to_string_lossy().into_owned();
        if name.starts_with("bad_") && name.ends_with(".rs") {
            on_disk.insert(name);
        }
    }
    for name in &on_disk {
        if !FIXTURE_EXPECT.iter().any(|(n, _)| n == name) {
            return Err(format!("fixture {name} exists on disk but is not in FIXTURE_EXPECT"));
        }
    }
    for (name, _) in FIXTURE_EXPECT {
        if !on_disk.contains(*name) {
            return Err(format!("FIXTURE_EXPECT lists {name} but the fixture file is missing"));
        }
    }

    for (name, rule) in FIXTURE_EXPECT {
        let cfg = fixture_config(name, *rule == "env-registry");
        let found = run(&fixtures, &cfg)?;
        if found.is_empty() {
            return Err(format!("fixture {name} produced no findings (expected {rule})"));
        }
        for f in &found {
            if f.rule != *rule {
                return Err(format!("fixture {name} tripped an unexpected rule: {}", f.render()));
            }
        }
    }

    let cfg = fixture_config("good_clean.rs", true);
    let found = run(&fixtures, &cfg)?;
    if !found.is_empty() {
        let shown: Vec<String> = found.iter().map(Finding::render).collect();
        return Err(format!("good_clean.rs must be clean, got: {}", shown.join("; ")));
    }

    Ok(format!(
        "self-test ok: {} bad fixtures each tripped exactly their rule; good_clean.rs clean",
        FIXTURE_EXPECT.len()
    ))
}

/// Config for one fixture run: every rule scoped to exactly that file.
/// The env registry is only attached where the fixture exercises it, so
/// stale-registry noise cannot leak into the other fixtures' runs.
fn fixture_config(name: &str, with_registry: bool) -> Config {
    let mut cfg = Config::default();
    for rule in KNOWN_RULES {
        let mut rc = RuleCfg { scope: vec![name.to_string()], ..Default::default() };
        if *rule == "env-registry" {
            if with_registry {
                rc.registry = Some("registry.md".to_string());
            } else {
                rc.scope.clear();
            }
        }
        cfg.rules.insert(rule.to_string(), rc);
    }
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_test_passes_on_the_committed_fixtures() {
        let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
        self_test(manifest).expect("fixture self-test");
    }
}
