//! The rule engine: given one lexed file, emit findings. Each rule is a
//! line-level token check over comment/string-blanked code (see
//! [`crate::lex`]), so `"panic!"` in a log message or a doc comment is
//! never a violation. Inline `// lint:allow(<rule>) -- <reason>`
//! pragmas suppress a rule on the pragma's own line and the next one;
//! a pragma with no reason is itself a finding.

use crate::config::{path_in, Config, KNOWN_RULES};
use crate::lex::{lex, test_regions, LineInfo};
use std::collections::{BTreeMap, BTreeSet};

/// One violation, root-relative, 1-indexed.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// An `SPNGD_*` env-var occurrence in a source string literal; the
/// registry cross-check in [`crate::run`] consumes these.
#[derive(Debug, Clone)]
pub struct EnvRead {
    pub file: String,
    pub line: usize,
    pub var: String,
}

/// Tokens the panic-hygiene rule forbids in parser modules.
const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

/// Wall-clock and iteration-order nondeterminism sources forbidden in
/// step-math and dist reduction paths.
const DET_TOKENS: &[&str] = &["Instant::now", "SystemTime::now", "HashMap", "HashSet"];

/// Raw output macros; library code must route through `util::log`/obs.
const PRINT_TOKENS: &[&str] = &["println!", "print!", "eprintln!", "eprint!", "dbg!"];

fn ident(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Token search over blanked code with identifier boundaries on the
/// ends that are identifier characters (so `HashMap` does not match
/// `XHashMapY`, but `.unwrap()` needs no left boundary).
fn has_token(code: &str, tok: &str) -> bool {
    let b = code.as_bytes();
    let t = tok.as_bytes();
    if t.is_empty() || b.len() < t.len() {
        return false;
    }
    let bound_pre = ident(t[0]);
    let bound_post = ident(t[t.len() - 1]);
    for at in 0..=b.len() - t.len() {
        if &b[at..at + t.len()] != t {
            continue;
        }
        if bound_pre && at > 0 && ident(b[at - 1]) {
            continue;
        }
        if bound_post && at + t.len() < b.len() && ident(b[at + t.len()]) {
            continue;
        }
        return true;
    }
    false
}

/// `expr[` indexing: a `[` directly preceded by an identifier char,
/// `)`, `]` or `?`. Array types `[u8; 4]`, attributes `#[...]` and
/// macro brackets `vec![` all have a different preceding character.
fn has_bare_index(code: &str) -> bool {
    let b = code.as_bytes();
    for i in 1..b.len() {
        if b[i] == b'[' && (ident(b[i - 1]) || matches!(b[i - 1], b')' | b']' | b'?')) {
            return true;
        }
    }
    false
}

/// Extract complete `SPNGD_*` tokens from a string literal (or a
/// registry table row — both sides use the same tokenizer so they can
/// never disagree). A token ending in `_` is a namespace prefix (e.g.
/// `"SPNGD_PROC_"` used to build names dynamically), not a var read,
/// and is skipped.
pub(crate) fn env_vars(s: &str) -> Vec<String> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 6 <= b.len() {
        if &b[i..i + 6] == b"SPNGD_" && (i == 0 || !ident(b[i - 1])) {
            let tail = |c: u8| c.is_ascii_uppercase() || c.is_ascii_digit() || c == b'_';
            let mut j = i + 6;
            while j < b.len() && tail(b[j]) {
                j += 1;
            }
            if b[j - 1] != b'_' {
                out.push(String::from_utf8_lossy(&b[i..j]).into_owned());
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Suppressions gathered from `lint:allow` pragmas: 1-indexed line →
/// rules allowed on that line.
#[derive(Debug, Default)]
pub struct Pragmas {
    map: BTreeMap<usize, BTreeSet<String>>,
}

impl Pragmas {
    pub fn allows(&self, line: usize, rule: &str) -> bool {
        self.map.get(&line).is_some_and(|s| s.contains(rule))
    }
}

/// Parse pragmas out of comment text. Returns the suppression table
/// plus findings (rule `pragma`) for malformed pragmas: unknown rule
/// names, or a missing `-- <reason>` trailer.
pub fn collect_pragmas(rel: &str, lines: &[LineInfo]) -> (Pragmas, Vec<Finding>) {
    const NEEDLE: &str = "lint:allow(";
    let mut pragmas = Pragmas::default();
    let mut findings = Vec::new();
    let mut bad = |line: usize, msg: String| {
        findings.push(Finding { file: rel.to_string(), line, rule: "pragma".into(), msg });
    };
    for (i, li) in lines.iter().enumerate() {
        let ln = i + 1;
        let Some(pos) = li.comment.find(NEEDLE) else { continue };
        let after = &li.comment[pos + NEEDLE.len()..];
        let Some(close) = after.find(')') else {
            bad(ln, "malformed lint:allow pragma: missing `)`".into());
            continue;
        };
        let mut rules = Vec::new();
        for r in after[..close].split(',') {
            let r = r.trim();
            if r.is_empty() {
                continue;
            }
            if KNOWN_RULES.contains(&r) {
                rules.push(r.to_string());
            } else {
                bad(ln, format!("lint:allow pragma names unknown rule `{r}`"));
            }
        }
        if rules.is_empty() {
            bad(ln, "lint:allow pragma allows no known rule".into());
        }
        let reason_ok = after[close + 1..]
            .trim_start()
            .strip_prefix("--")
            .is_some_and(|r| !r.trim().is_empty());
        if !reason_ok {
            bad(ln, "lint:allow pragma is missing its `-- <reason>` trailer".into());
        }
        for r in rules {
            pragmas.map.entry(ln).or_default().insert(r.clone());
            pragmas.map.entry(ln + 1).or_default().insert(r);
        }
    }
    (pragmas, findings)
}

/// Scan one file against every scoped rule. `env_reads` accumulates
/// `SPNGD_*` string occurrences for the cross-file registry check.
pub fn scan_file(
    rel: &str,
    text: &str,
    cfg: &Config,
    env_reads: &mut Vec<EnvRead>,
) -> Vec<Finding> {
    let lines = lex(text);
    let in_test = test_regions(&lines);
    let (pragmas, mut findings) = collect_pragmas(rel, &lines);
    let mut push = |line: usize, rule: &str, msg: String, out: &mut Vec<Finding>| {
        out.push(Finding { file: rel.to_string(), line, rule: rule.to_string(), msg });
    };

    let scoped = |rule: &str| {
        let rc = cfg.rule(rule);
        path_in(rel, &rc.scope) && !path_in(rel, &rc.allow)
    };

    if scoped("panic-hygiene") {
        let check_index = !path_in(rel, &cfg.rule("panic-hygiene").index_allow);
        for (i, li) in lines.iter().enumerate() {
            let ln = i + 1;
            if in_test[i] || pragmas.allows(ln, "panic-hygiene") {
                continue;
            }
            for tok in PANIC_TOKENS {
                if has_token(&li.code, tok) {
                    let msg = format!("`{tok}` in a structured-error parser module");
                    push(ln, "panic-hygiene", msg, &mut findings);
                }
            }
            if check_index && has_bare_index(&li.code) {
                let msg = "slice indexing in a parser module (use get()/take-then-index)".into();
                push(ln, "panic-hygiene", msg, &mut findings);
            }
        }
    }

    if scoped("determinism") {
        for (i, li) in lines.iter().enumerate() {
            let ln = i + 1;
            if in_test[i] || pragmas.allows(ln, "determinism") {
                continue;
            }
            for tok in DET_TOKENS {
                if has_token(&li.code, tok) {
                    let msg = format!("nondeterminism source `{tok}` in a step-math/dist path");
                    push(ln, "determinism", msg, &mut findings);
                }
            }
        }
    }

    // unsafe-audit applies everywhere, test regions included: a wrong
    // SAFETY story in a test is still a wrong SAFETY story.
    if scoped("unsafe-audit") {
        for (i, li) in lines.iter().enumerate() {
            let ln = i + 1;
            if !has_token(&li.code, "unsafe") || pragmas.allows(ln, "unsafe-audit") {
                continue;
            }
            if !safety_documented(&lines, i) {
                let msg = "`unsafe` without an adjacent `// SAFETY:` comment".into();
                push(ln, "unsafe-audit", msg, &mut findings);
            }
        }
    }

    if scoped("thread-naming") {
        for (i, li) in lines.iter().enumerate() {
            let ln = i + 1;
            if in_test[i] || pragmas.allows(ln, "thread-naming") {
                continue;
            }
            if has_token(&li.code, "thread::spawn") {
                let msg = "bare thread::spawn — use thread::Builder::new().name(...)".into();
                push(ln, "thread-naming", msg, &mut findings);
            }
            if has_token(&li.code, "thread::Builder") {
                let window: String = lines[i..lines.len().min(i + 6)]
                    .iter()
                    .map(|l| l.code.as_str())
                    .collect::<Vec<_>>()
                    .join(" ");
                if !window.contains(".name(") {
                    let msg = "thread::Builder spawn without .name(...)".into();
                    push(ln, "thread-naming", msg, &mut findings);
                }
            }
        }
    }

    if scoped("no-raw-print") {
        for (i, li) in lines.iter().enumerate() {
            let ln = i + 1;
            if in_test[i] || pragmas.allows(ln, "no-raw-print") {
                continue;
            }
            for tok in PRINT_TOKENS {
                if has_token(&li.code, tok) {
                    let msg = format!("raw `{tok}` in library code (route through util::log/obs)");
                    push(ln, "no-raw-print", msg, &mut findings);
                }
            }
        }
    }

    if scoped("env-registry") {
        for (i, li) in lines.iter().enumerate() {
            let ln = i + 1;
            if pragmas.allows(ln, "env-registry") {
                continue;
            }
            for s in &li.strings {
                for var in env_vars(s) {
                    env_reads.push(EnvRead { file: rel.to_string(), line: ln, var });
                }
            }
        }
    }

    findings
}

/// A SAFETY comment counts when it sits in the `unsafe` line's own
/// comment or in the contiguous comment/attribute block directly above
/// (doc comments and `#[target_feature]` attributes may interleave).
fn safety_documented(lines: &[LineInfo], at: usize) -> bool {
    let hit = |c: &str| c.contains("SAFETY:") || c.contains("# Safety");
    if hit(&lines[at].comment) {
        return true;
    }
    let mut j = at;
    while j > 0 {
        j -= 1;
        let lj = &lines[j];
        let code_t = lj.code.trim();
        if !code_t.is_empty() && !code_t.starts_with("#[") {
            return false;
        }
        if hit(&lj.comment) {
            return true;
        }
        if code_t.is_empty() && lj.comment.trim().is_empty() {
            return false; // blank line ends the block
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_boundaries() {
        assert!(has_token("let m = HashMap::new();", "HashMap"));
        assert!(!has_token("let m = MyHashMap::new();", "HashMap"));
        assert!(has_token("x.unwrap();", ".unwrap()"));
        assert!(!has_token("eprint_buffer()", "print!"));
        assert!(has_token("std::thread::spawn(f)", "thread::spawn"));
    }

    #[test]
    fn index_detection() {
        assert!(has_bare_index("let x = buf[0];"));
        assert!(has_bare_index("take(2)?[1]"));
        assert!(!has_bare_index("#[derive(Debug)]"));
        assert!(!has_bare_index("fn f(b: &[u8]) -> [f32; 4] { vec![] }"));
    }

    #[test]
    fn env_var_extraction() {
        assert_eq!(env_vars("SPNGD_THREADS"), vec!["SPNGD_THREADS".to_string()]);
        assert_eq!(env_vars("prefix SPNGD_PROC_ suffix"), Vec::<String>::new());
        assert_eq!(env_vars("XSPNGD_THREADS"), Vec::<String>::new());
    }

    #[test]
    fn pragma_suppresses_and_requires_reason() {
        let src = "// lint:allow(determinism) -- timer is telemetry-only\n\
                   let t = Instant::now();\n\
                   // lint:allow(determinism)\n\
                   let u = Instant::now();\n";
        let lines = lex(src);
        let (pragmas, bad) = collect_pragmas("x.rs", &lines);
        assert!(pragmas.allows(2, "determinism"));
        assert!(pragmas.allows(4, "determinism"));
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].rule, "pragma");
        assert_eq!(bad[0].line, 3);
    }

    #[test]
    fn safety_block_scans_past_attributes() {
        let src = "/// docs\n/// # Safety\n/// callers check avx2\n\
                   #[target_feature(enable = \"avx2\")]\npub unsafe fn f() {}\n";
        let lines = lex(src);
        assert!(safety_documented(&lines, 4));
        let src2 = "fn g() {}\npub unsafe fn f() {}\n";
        let lines2 = lex(src2);
        assert!(!safety_documented(&lines2, 1));
    }
}
