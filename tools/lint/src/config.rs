//! `lint.toml` loader — a minimal TOML subset (sections, string and
//! string-array values, `#` comments, multi-line arrays). Kept
//! dependency-free on purpose: the lint must build in the same offline
//! cell as the rest of the workspace.

use std::collections::BTreeMap;
use std::path::Path;

/// The rule names the scanner knows. A config section or pragma naming
/// anything else is rejected loudly — a typo'd rule must not silently
/// disable enforcement.
pub const KNOWN_RULES: &[&str] = &[
    "panic-hygiene",
    "determinism",
    "unsafe-audit",
    "thread-naming",
    "no-raw-print",
    "env-registry",
];

/// Per-rule configuration. Paths are root-relative with `/` separators;
/// an entry matches a file exactly or any file under it as a directory.
#[derive(Debug, Default, Clone)]
pub struct RuleCfg {
    /// Files/dirs the rule scans. Empty scope disables the rule.
    pub scope: Vec<String>,
    /// Files/dirs exempted from the rule entirely.
    pub allow: Vec<String>,
    /// panic-hygiene only: files whose `[]` indexing is waived (the
    /// check-then-index ByteReader discipline, proven total by fuzzing).
    pub index_allow: Vec<String>,
    /// env-registry only: root-relative markdown file whose table rows
    /// form the registry.
    pub registry: Option<String>,
}

/// Parsed lint configuration: one [`RuleCfg`] per known rule.
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub rules: BTreeMap<String, RuleCfg>,
}

impl Config {
    /// Look up a rule's config; rules absent from the file are disabled.
    pub fn rule(&self, name: &str) -> RuleCfg {
        self.rules.get(name).cloned().unwrap_or_default()
    }

    pub fn load(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read config {}: {e}", path.display()))?;
        Config::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section: Option<String> = None;
        let mut lines = text.lines().enumerate();
        while let Some((idx, raw)) = lines.next() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if !KNOWN_RULES.contains(&name) {
                    return Err(format!("line {}: unknown rule section [{name}]", idx + 1));
                }
                cfg.rules.entry(name.to_string()).or_default();
                section = Some(name.to_string());
                continue;
            }
            let (key, mut val) = line
                .split_once('=')
                .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                .ok_or_else(|| format!("line {}: expected `key = value`", idx + 1))?;
            // multi-line array: keep consuming until brackets balance
            if val.starts_with('[') {
                while !brackets_balance(&val) {
                    match lines.next() {
                        Some((_, more)) => {
                            val.push(' ');
                            val.push_str(strip_comment(more).trim());
                        }
                        None => return Err(format!("line {}: unterminated array", idx + 1)),
                    }
                }
            }
            let sect = section
                .clone()
                .ok_or_else(|| format!("line {}: key `{key}` outside a [rule] section", idx + 1))?;
            let rule = cfg.rules.entry(sect).or_default();
            match key.as_str() {
                "scope" => rule.scope = parse_str_list(&val, idx + 1)?,
                "allow" => rule.allow = parse_str_list(&val, idx + 1)?,
                "index-allow" => rule.index_allow = parse_str_list(&val, idx + 1)?,
                "registry" => rule.registry = Some(parse_str(&val, idx + 1)?),
                other => return Err(format!("line {}: unknown key `{other}`", idx + 1)),
            }
        }
        Ok(cfg)
    }
}

/// Drop a trailing `#` comment, ignoring `#` inside quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn brackets_balance(val: &str) -> bool {
    let mut depth = 0i64;
    let mut in_str = false;
    for c in val.chars() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            _ => {}
        }
    }
    depth == 0 && !in_str
}

fn parse_str(val: &str, line: usize) -> Result<String, String> {
    let v = val.trim();
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .map(str::to_string)
        .ok_or_else(|| format!("line {line}: expected a quoted string, got `{val}`"))
}

fn parse_str_list(val: &str, line: usize) -> Result<Vec<String>, String> {
    let v = val.trim();
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("line {line}: expected an array, got `{val}`"))?;
    let mut out = Vec::new();
    let mut rest = inner;
    while let Some(start) = rest.find('"') {
        let tail = &rest[start + 1..];
        let end = tail
            .find('"')
            .ok_or_else(|| format!("line {line}: unterminated string in array"))?;
        out.push(tail[..end].to_string());
        rest = &tail[end + 1..];
    }
    Ok(out)
}

/// True when root-relative `path` equals `entry` or lies under it.
pub fn path_matches(path: &str, entry: &str) -> bool {
    path == entry || path.starts_with(&format!("{entry}/"))
}

/// True when `path` matches any entry in `entries`.
pub fn path_in(path: &str, entries: &[String]) -> bool {
    entries.iter().any(|e| path_matches(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_arrays_and_comments() {
        let text = r#"
# top comment
[determinism]
scope = ["rust/src/optim", # trailing comment
         "rust/src/dist"]
allow = ["rust/src/dist/membership.rs"]

[env-registry]
scope = ["rust/src"]
registry = "README.md"
"#;
        let cfg = Config::parse(text).unwrap();
        let det = cfg.rule("determinism");
        assert_eq!(det.scope.len(), 2);
        assert_eq!(det.allow, vec!["rust/src/dist/membership.rs".to_string()]);
        assert_eq!(cfg.rule("env-registry").registry.as_deref(), Some("README.md"));
        assert!(cfg.rule("no-raw-print").scope.is_empty());
    }

    #[test]
    fn rejects_unknown_rules_and_keys() {
        assert!(Config::parse("[made-up-rule]\nscope = []\n").is_err());
        assert!(Config::parse("[determinism]\nbogus = []\n").is_err());
        assert!(Config::parse("scope = []\n").is_err());
    }

    #[test]
    fn path_matching_is_prefix_by_component() {
        assert!(path_matches("rust/src/ckpt/bytes.rs", "rust/src/ckpt"));
        assert!(path_matches("rust/src/ckpt", "rust/src/ckpt"));
        assert!(!path_matches("rust/src/ckpt2/x.rs", "rust/src/ckpt"));
    }
}
