// Negative fixture: anonymous threads — a bare spawn and a Builder
// that never calls .name(). This file is never compiled.

pub fn fire_and_forget() {
    std::thread::spawn(|| {});
    let _ = std::thread::Builder::new()
        .stack_size(1 << 20)
        .spawn(|| {});
}
