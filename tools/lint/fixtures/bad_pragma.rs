// Negative fixture: the pragma below suppresses the print on the next
// line, but it has no `-- <reason>` trailer, which is itself a
// finding (rule `pragma`). This file is never compiled.

pub fn report(loss: f32) {
    // lint:allow(no-raw-print)
    println!("loss = {loss}");
}
