// Negative fixture: an `unsafe` block with no adjacent SAFETY comment.
// This file is never compiled.

pub fn read_first(v: &[f32]) -> f32 {
    let p = v.as_ptr();

    unsafe { *p }
}
