// Negative fixture: reads an SPNGD_* env var that registry.md does not
// list (and registry.md lists SPNGD_FAKE_VAR, which this file does not
// read — both directions must be flagged). This file is never compiled.

pub fn knob() -> Option<String> {
    std::env::var("SPNGD_NOT_IN_REGISTRY").ok()
}
