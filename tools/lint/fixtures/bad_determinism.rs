// Negative fixture: nondeterminism sources in what the config treats
// as a step-math path. This file is never compiled.

use std::collections::HashMap;
use std::time::Instant;

pub fn reduce(grads: &HashMap<String, f32>) -> f32 {
    let t = Instant::now();
    let sum: f32 = grads.values().sum();
    sum + t.elapsed().as_secs_f32() * 0.0
}
