// Negative fixture: raw output macros in library code. This file is
// never compiled.

pub fn report(loss: f32) {
    println!("loss = {loss}");
    eprintln!("debug: {loss}");
}
