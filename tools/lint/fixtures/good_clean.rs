// Positive fixture: every forbidden token below sits where the lexer
// must NOT look — string literals, raw strings, char literals, block
// comments, cfg(test) regions — or is explicitly suppressed by a
// well-formed pragma. The self-test requires ZERO findings here, so
// any lexer regression (string state, nesting, char-vs-lifetime)
// surfaces as a self-test failure. This file is never compiled.

/* block comment with panic! and .unwrap() tokens
   /* nested block: thread::spawn(|| {}) println!("x") */
   still inside the outer comment: HashMap Instant::now
*/

pub fn strings_are_not_code() -> String {
    let s = "panic! .unwrap() HashMap println! unsafe buf[0]";
    let q = "escaped quote \" then .expect( inside";
    let r = r#"raw string: Instant::now() and v[1] and "quoted""#;
    let multi = "line one panic!
line two HashMap";
    format!("{s}{q}{r}{multi}")
}

pub fn char_literals_are_not_strings() -> (char, char, char) {
    let quote = '"';
    let escaped = '\'';
    let bracket = '[';
    (quote, escaped, bracket)
}

pub fn lifetimes_are_not_chars<'a>(x: &'a [u8]) -> &'a [u8] {
    x
}

pub fn env_read_is_registered() -> Option<String> {
    // SPNGD_SCRATCH_ below is a namespace prefix (trailing underscore),
    // not a var read, and must not require registration.
    let _prefix = "SPNGD_SCRATCH_";
    std::env::var("SPNGD_FAKE_VAR").ok()
}

pub fn suppressed_with_reason() -> usize {
    // lint:allow(determinism) -- fixture exercises pragma suppression
    let m: std::collections::HashMap<u8, u8> = std::collections::HashMap::new();
    m.len()
}

pub fn documented_unsafe(v: &[f32]) -> f32 {
    if v.is_empty() {
        return 0.0;
    }
    let p = v.as_ptr();
    // SAFETY: v is non-empty (checked above), so reading element 0
    // through as_ptr() stays in bounds.
    unsafe { *p }
}

pub fn named_thread() {
    let _ = std::thread::Builder::new()
        .name("spngd-clean-fixture".to_string())
        .spawn(|| {});
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap_and_print() {
        let v: Vec<u32> = vec![1];
        let first = v.first().copied().unwrap();
        println!("test output {first}");
        assert!(std::panic::catch_unwind(|| panic!("boom")).is_err());
    }
}
