// Negative fixture: panic paths in what the config treats as a parser
// module. Every flagged line must trip `panic-hygiene` and nothing
// else. This file is never compiled.

pub fn parse(buf: &[u8]) -> u32 {
    let first = buf[0];
    let rest: u32 = std::str::from_utf8(&buf[1..]).unwrap().parse().expect("digits");
    if first == 0 {
        panic!("zero tag");
    }
    u32::from(first) + rest
}
